//! Index rectification — the kernel-slicing transform (paper §4.1,
//! Fig. 3c).
//!
//! A slice is launched with a small grid, so the built-in `%ctaid`
//! values are in the slice's index space. To make the slice execute the
//! same thread blocks the full grid would have, Kernelet:
//!
//! 1. appends parameters `__koff_x`, `__koff_y` (the slice's block
//!    offset) and `__kgrid_x`, `__kgrid_y` (the *original* grid shape);
//! 2. computes rectified indices in a prologue:
//!    `rX = %ctaid.x + off.x`, then (2-D) wraps `rX` into the original
//!    X extent, carrying overflow into `rY` — the Fig. 3c while-loops;
//! 3. replaces every subsequent read of `%ctaid.x`/`%ctaid.y` with the
//!    rectified registers;
//! 4. replaces reads of `%nctaid.*` with the original grid shape (a
//!    sliced launch must still see the full grid's extent);
//! 5. prunes now-dead register declarations so that, with the liveness
//!    cleanup, "register usage by slicing keeps unchanged in most
//!    cases".
//!
//! The transform is one linear scan over the instructions plus the
//! constant-size prologue, matching the paper's "single scan ...
//! runtime overhead is negligible".

use super::ast::*;
use super::liveness::prune_dead_decls;

/// Rectification options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RectifyOptions {
    /// Grid dimensionality of the target kernel (1 or 2).
    pub dims: u32,
}

impl RectifyOptions {
    /// Options for a 1-D grid.
    pub fn one_d() -> Self {
        Self { dims: 1 }
    }

    /// Options for a 2-D grid.
    pub fn two_d() -> Self {
        Self { dims: 2 }
    }
}

/// Names of the injected parameters, in order.
pub const OFFSET_X: &str = "__koff_x";
/// Injected y-offset parameter name (2-D grids).
pub const OFFSET_Y: &str = "__koff_y";
/// Injected original-grid-x parameter name.
pub const GRID_X: &str = "__kgrid_x";
/// Injected original-grid-y parameter name (2-D grids).
pub const GRID_Y: &str = "__kgrid_y";

/// Apply index rectification, producing the sliced kernel.
pub fn rectify(k: &Kernel, opts: &RectifyOptions) -> Kernel {
    assert!(opts.dims == 1 || opts.dims == 2, "1-D or 2-D grids only");
    let mut out = k.clone();

    // 1. Inject parameters.
    out.params.push((OFFSET_X.into(), Type::U32));
    out.params.push((GRID_X.into(), Type::U32));
    if opts.dims == 2 {
        out.params.push((OFFSET_Y.into(), Type::U32));
        out.params.push((GRID_Y.into(), Type::U32));
    }

    // 2. Fresh registers for the rectified indices and grid extents.
    let rx = out.fresh_reg("krx");
    let gx = out.fresh_reg("kgx");
    out.regs.push((rx.clone(), Type::U32));
    out.regs.push((gx.clone(), Type::U32));
    let (ry, gy) = if opts.dims == 2 {
        let ry = out.fresh_reg("kry");
        let gy = out.fresh_reg("kgy");
        out.regs.push((ry.clone(), Type::U32));
        out.regs.push((gy.clone(), Type::U32));
        (Some(ry), Some(gy))
    } else {
        (None, None)
    };

    // 3. Prologue (Fig. 3c).
    let mut prologue: Vec<Inst> = Vec::new();
    prologue.push(Inst::Ld {
        space: Space::Param,
        ty: Type::U32,
        dst: gx.clone(),
        addr: Addr { base: Reg(GRID_X.into()), offset: 0 },
    });
    // rX = ctaid.x + __koff_x (offset loaded into rX first, then add).
    prologue.push(Inst::Ld {
        space: Space::Param,
        ty: Type::U32,
        dst: rx.clone(),
        addr: Addr { base: Reg(OFFSET_X.into()), offset: 0 },
    });
    prologue.push(Inst::Bin {
        op: BinOp::Add,
        ty: Type::U32,
        dst: rx.clone(),
        a: Operand::Reg(rx.clone()),
        b: Operand::Special(Special::CtaIdX),
    });
    if let (Some(ry), Some(gy)) = (&ry, &gy) {
        prologue.push(Inst::Ld {
            space: Space::Param,
            ty: Type::U32,
            dst: gy.clone(),
            addr: Addr { base: Reg(GRID_Y.into()), offset: 0 },
        });
        prologue.push(Inst::Ld {
            space: Space::Param,
            ty: Type::U32,
            dst: ry.clone(),
            addr: Addr { base: Reg(OFFSET_Y.into()), offset: 0 },
        });
        prologue.push(Inst::Bin {
            op: BinOp::Add,
            ty: Type::U32,
            dst: ry.clone(),
            a: Operand::Reg(ry.clone()),
            b: Operand::Special(Special::CtaIdY),
        });
        // while (rX >= gridX) { rX -= gridX; rY += 1; }  — the Fig. 3c
        // wrap-around normalization, emitted as a compare/branch loop.
        let p = out.fresh_reg("kwp");
        out.regs.push((p.clone(), Type::Pred));
        prologue.push(Inst::Label("KWRAP".into()));
        prologue.push(Inst::Setp {
            cmp: Cmp::Lt,
            ty: Type::U32,
            dst: p.clone(),
            a: Operand::Reg(rx.clone()),
            b: Operand::Reg(gx.clone()),
        });
        prologue.push(Inst::Bra { pred: Some((p.clone(), true)), target: "KWRAPEND".into() });
        prologue.push(Inst::Bin {
            op: BinOp::Sub,
            ty: Type::U32,
            dst: rx.clone(),
            a: Operand::Reg(rx.clone()),
            b: Operand::Reg(gx.clone()),
        });
        prologue.push(Inst::Bin {
            op: BinOp::Add,
            ty: Type::U32,
            dst: ry.clone(),
            a: Operand::Reg(ry.clone()),
            b: Operand::Imm(1),
        });
        prologue.push(Inst::Bra { pred: None, target: "KWRAP".into() });
        prologue.push(Inst::Label("KWRAPEND".into()));
    }

    // 4. Substitute reads of the built-ins in the original body.
    let mut body = prologue;
    for inst in &out.body {
        let mut inst = inst.clone();
        inst.map_operands(&mut |o| {
            if let Operand::Special(sp) = o {
                match sp {
                    Special::CtaIdX => *o = Operand::Reg(rx.clone()),
                    Special::CtaIdY => {
                        if let Some(ry) = &ry {
                            *o = Operand::Reg(ry.clone());
                        }
                    }
                    Special::NCtaIdX => *o = Operand::Reg(gx.clone()),
                    Special::NCtaIdY => {
                        if let Some(gy) = &gy {
                            *o = Operand::Reg(gy.clone());
                        }
                    }
                    _ => {}
                }
            }
        });
        body.push(inst);
    }
    out.body = body;

    // 5. Register cleanup (the paper's liveness-based minimization).
    prune_dead_decls(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::liveness::max_pressure;
    use crate::ptx::parser::parse_kernel;
    use crate::ptx::samples;

    #[test]
    fn one_d_adds_two_params() {
        let k = parse_kernel(samples::SAXPY).unwrap();
        let s = rectify(&k, &RectifyOptions::one_d());
        assert_eq!(s.params.len(), k.params.len() + 2);
        assert_eq!(s.params[s.params.len() - 2].0, OFFSET_X);
    }

    #[test]
    fn two_d_adds_four_params_and_wrap_loop() {
        let k = parse_kernel(samples::MATRIX_ADD).unwrap();
        let s = rectify(&k, &RectifyOptions::two_d());
        assert_eq!(s.params.len(), k.params.len() + 4);
        assert!(s.body.iter().any(|i| matches!(i, Inst::Label(l) if l == "KWRAP")));
    }

    #[test]
    fn no_ctaid_reads_remain() {
        for (name, src) in samples::all() {
            let k = parse_kernel(src).unwrap();
            let s = rectify(&k, &RectifyOptions::two_d());
            // Prologue reads %ctaid once to rebase; all other reads
            // must be gone. Count total ctaid reads: exactly dims.
            let reads: usize = s
                .body
                .iter()
                .map(|i| {
                    i.specials()
                        .iter()
                        .filter(|sp| matches!(sp, Special::CtaIdX | Special::CtaIdY))
                        .count()
                })
                .sum();
            assert_eq!(reads, 2, "{name}: {reads} raw ctaid reads left");
        }
    }

    #[test]
    fn register_pressure_increase_is_bounded() {
        // The paper: "register usage by slicing keeps unchanged in most
        // of our test cases". Our transform may add the rectified pair;
        // assert the pressure increase is at most the injected
        // registers (2 for 1-D).
        for (name, src) in samples::all() {
            let k = parse_kernel(src).unwrap();
            let before = max_pressure(&k);
            let s = rectify(&k, &RectifyOptions::one_d());
            let after = max_pressure(&s);
            assert!(
                after <= before + 2,
                "{name}: pressure {before} -> {after}"
            );
        }
    }

    #[test]
    fn rectified_kernel_emits_and_reparses() {
        let k = parse_kernel(samples::GATHER).unwrap();
        let s = rectify(&k, &RectifyOptions::one_d());
        let text = crate::ptx::emit::emit(&s);
        let re = parse_kernel(&text).unwrap();
        assert_eq!(re.body, s.body);
    }
}
