//! Hand-written PTX sources for representative kernels.
//!
//! `MATRIX_ADD` is the paper's running example (Fig. 3): a 2-D grid
//! where each thread adds one element pair. The others cover the 1-D
//! streaming, gather and branchy patterns the benchmark suite models
//! statistically, so the rectifier + interpreter round-trip is
//! exercised over every control-flow shape the subset supports.

/// Fig. 3 MatrixAdd: C[row,col] = A + B over a `width`-wide matrix.
/// Launched with a 2-D grid; each block covers 16x16 elements.
pub const MATRIX_ADD: &str = r#"
.version 3.1
.target sm_20
.address_size 64

.visible .entry matrix_add (
    .param .u64 pA,
    .param .u64 pB,
    .param .u32 pWidth
) {
    .reg .u32 %r<8>;
    .reg .u64 %rd<6>;
    .reg .f32 %f<3>;

    ld.param.u64 %rd0, [pA];
    ld.param.u64 %rd1, [pB];
    ld.param.u32 %r6, [pWidth];

    // row = ctaid.x * ntid.x + tid.x
    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mul.lo.u32 %r2, %r0, %r1;
    mov.u32 %r3, %tid.x;
    add.u32 %r2, %r2, %r3;

    // col = ctaid.y * ntid.y + tid.y
    mov.u32 %r0, %ctaid.y;
    mov.u32 %r1, %ntid.y;
    mul.lo.u32 %r4, %r0, %r1;
    mov.u32 %r5, %tid.y;
    add.u32 %r4, %r4, %r5;

    // idx = row + col * width
    mul.lo.u32 %r7, %r4, %r6;
    add.u32 %r7, %r7, %r2;

    // A[idx] += B[idx]
    mul.wide.u32 %rd2, %r7, 4;
    add.u64 %rd3, %rd0, %rd2;
    add.u64 %rd4, %rd1, %rd2;
    ld.global.f32 %f0, [%rd3];
    ld.global.f32 %f1, [%rd4];
    add.f32 %f2, %f0, %f1;
    st.global.f32 [%rd3], %f2;
    ret;
}
"#;

/// 1-D SAXPY: y[i] = a*x[i] + y[i] with a bounds check.
pub const SAXPY: &str = r#"
.visible .entry saxpy (
    .param .u64 pX,
    .param .u64 pY,
    .param .f32 pA,
    .param .u32 pN
) {
    .reg .u32 %r<5>;
    .reg .u64 %rd<5>;
    .reg .f32 %f<4>;
    .reg .pred %p<1>;

    ld.param.u64 %rd0, [pX];
    ld.param.u64 %rd1, [pY];
    ld.param.f32 %f0, [pA];
    ld.param.u32 %r3, [pN];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mad.lo.u32 %r2, %r0, %r1, 0;
    mov.u32 %r4, %tid.x;
    add.u32 %r2, %r2, %r4;

    setp.ge.u32 %p0, %r2, %r3;
    @%p0 bra DONE;

    mul.wide.u32 %rd2, %r2, 4;
    add.u64 %rd3, %rd0, %rd2;
    add.u64 %rd4, %rd1, %rd2;
    ld.global.f32 %f1, [%rd3];
    ld.global.f32 %f2, [%rd4];
    fma.rn.f32 %f3, %f0, %f1, %f2;
    st.global.f32 [%rd4], %f3;
DONE:
    ret;
}
"#;

/// 1-D gather (pointer-chase flavour): out[i] = data[idx[i]].
pub const GATHER: &str = r#"
.visible .entry gather (
    .param .u64 pIdx,
    .param .u64 pData,
    .param .u64 pOut
) {
    .reg .u32 %r<4>;
    .reg .u64 %rd<8>;
    .reg .f32 %f<1>;

    ld.param.u64 %rd0, [pIdx];
    ld.param.u64 %rd1, [pData];
    ld.param.u64 %rd2, [pOut];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mov.u32 %r2, %tid.x;
    mad.lo.u32 %r3, %r0, %r1, 0;
    add.u32 %r3, %r3, %r2;

    mul.wide.u32 %rd3, %r3, 4;
    add.u64 %rd4, %rd0, %rd3;
    ld.global.u32 %r0, [%rd4];
    mul.wide.u32 %rd5, %r0, 4;
    add.u64 %rd6, %rd1, %rd5;
    ld.global.f32 %f0, [%rd6];
    add.u64 %rd7, %rd2, %rd3;
    st.global.f32 [%rd7], %f0;
    ret;
}
"#;

/// Per-thread loop (TEA-round flavour): iteratively mixes a value.
pub const MIX_ROUNDS: &str = r#"
.visible .entry mix_rounds (
    .param .u64 pData,
    .param .u32 pRounds
) {
    .reg .u32 %r<8>;
    .reg .u64 %rd<3>;
    .reg .pred %p<1>;

    ld.param.u64 %rd0, [pData];
    ld.param.u32 %r4, [pRounds];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mov.u32 %r2, %tid.x;
    mad.lo.u32 %r3, %r0, %r1, 0;
    add.u32 %r3, %r3, %r2;

    mul.wide.u32 %rd1, %r3, 4;
    add.u64 %rd2, %rd0, %rd1;
    ld.global.u32 %r5, [%rd2];

    mov.u32 %r6, 0;
LOOP:
    setp.ge.u32 %p0, %r6, %r4;
    @%p0 bra DONE;
    shl.b32 %r7, %r5, 4;
    xor.b32 %r5, %r5, %r7;
    add.u32 %r5, %r5, %r3;
    add.u32 %r6, %r6, 1;
    bra LOOP;
DONE:
    st.global.u32 [%rd2], %r5;
    ret;
}
"#;

/// Bucketed count (histogram flavour): bins[data[i] & 15] += 1 via a
/// global atomic. The analyzer must classify this `Unsliceable`: with
/// slices launched as separate kernels, a co-runner's epoch can
/// observe a partially accumulated bin.
pub const HISTOGRAM: &str = r#"
.visible .entry histogram (
    .param .u64 pData,
    .param .u64 pBins
) {
    .reg .u32 %r<7>;
    .reg .u64 %rd<5>;

    ld.param.u64 %rd0, [pData];
    ld.param.u64 %rd1, [pBins];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mov.u32 %r2, %tid.x;
    mad.lo.u32 %r3, %r0, %r1, 0;
    add.u32 %r3, %r3, %r2;

    mul.wide.u32 %rd2, %r3, 4;
    add.u64 %rd3, %rd0, %rd2;
    ld.global.u32 %r4, [%rd3];
    and.b32 %r5, %r4, 15;
    mul.wide.u32 %rd4, %r5, 4;
    add.u64 %rd4, %rd1, %rd4;
    atom.global.add.u32 %r6, [%rd4], 1;
    ret;
}
"#;

/// Grid-tail special case: every thread writes its index, and the
/// last block (detected by comparing `%ctaid.x` against
/// `%nctaid.x - 1`) additionally writes a completion flag. The branch
/// predicate data-flows from `%nctaid`, so slicing (which launches
/// with a smaller grid) would move the "last block" — the analyzer
/// must classify this `Unsliceable`.
pub const TAIL_FLAG: &str = r#"
.visible .entry tail_flag (
    .param .u64 pOut
) {
    .reg .u32 %r<7>;
    .reg .u64 %rd<3>;
    .reg .pred %p<1>;

    ld.param.u64 %rd0, [pOut];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mov.u32 %r2, %tid.x;
    mad.lo.u32 %r3, %r0, %r1, 0;
    add.u32 %r3, %r3, %r2;

    mul.wide.u32 %rd1, %r3, 4;
    add.u64 %rd2, %rd0, %rd1;
    st.global.u32 [%rd2], %r3;

    // Only the last block writes the flag.
    sub.u32 %r4, %nctaid.x, 1;
    setp.ne.u32 %p0, %r0, %r4;
    @%p0 bra DONE;
    mov.u32 %r5, 1;
    st.global.u32 [%rd2+4096], %r5;
DONE:
    ret;
}
"#;

/// Block-local barrier use: load, `bar.sync`, then a pure per-thread
/// store. The barrier is uniform (no divergent branch reaches it) and
/// block-scoped, so this stays `SliceableWithRectify` — the analyzer
/// must not confuse block-level synchronization with grid-level
/// communication.
pub const BLOCK_BARRIER: &str = r#"
.visible .entry block_barrier (
    .param .u64 pIn,
    .param .u64 pOut
) {
    .reg .u32 %r<6>;
    .reg .u64 %rd<5>;

    ld.param.u64 %rd0, [pIn];
    ld.param.u64 %rd1, [pOut];

    mov.u32 %r0, %ctaid.x;
    mov.u32 %r1, %ntid.x;
    mov.u32 %r2, %tid.x;
    mad.lo.u32 %r3, %r0, %r1, 0;
    add.u32 %r3, %r3, %r2;

    mul.wide.u32 %rd2, %r3, 4;
    add.u64 %rd3, %rd0, %rd2;
    ld.global.u32 %r4, [%rd3];
    bar.sync 0;
    membar.cta;
    add.u32 %r5, %r4, %r3;
    add.u64 %rd4, %rd1, %rd2;
    st.global.u32 [%rd4], %r5;
    ret;
}
"#;

/// All samples with names, for sweep tests.
pub fn all() -> Vec<(&'static str, &'static str)> {
    vec![
        ("matrix_add", MATRIX_ADD),
        ("saxpy", SAXPY),
        ("gather", GATHER),
        ("mix_rounds", MIX_ROUNDS),
        ("histogram", HISTOGRAM),
        ("tail_flag", TAIL_FLAG),
        ("block_barrier", BLOCK_BARRIER),
    ]
}
