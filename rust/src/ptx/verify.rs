//! Differential rectify-verifier: the dynamic oracle paired with the
//! static [`super::analyze`] pass.
//!
//! For a kernel `k`, run the ORIGINAL PTX over a full grid and the
//! rectified PTX slice-by-slice (several slice sizes, several grid
//! shapes) on identically seeded memory, and assert the final memory
//! images are bit-identical. Arguments are synthesized from the
//! parameter types, so the check needs no per-kernel knowledge and
//! covers every sample in [`super::samples`] plus any user-supplied
//! kernel.
//!
//! Scope: the interpreter executes threads sequentially, so this
//! oracle proves the *index arithmetic* of rectification (offsets,
//! wrap-around, `%nctaid` substitution) — it cannot observe the
//! cross-slice interleavings that make atomics/fences unsafe. Those
//! are the static analyzer's verdict to make; an `Unsliceable` kernel
//! passing this oracle is expected, not a contradiction.

use anyhow::{bail, Context, Result};

use super::analyze::infer_dims;
use super::ast::{Kernel, Type};
use super::interp::{launch, Args, LaunchConfig, Machine};
use super::rectify::{rectify, RectifyOptions};

/// Global memory size for differential runs.
const MEM_BYTES: usize = 256 * 1024;
/// Stride between synthesized pointer arguments: each u64 parameter
/// gets its own 32 KiB region (region 0 is left for index data read
/// via small loaded values).
const REGION: usize = 32 * 1024;

/// Scalar value for synthesized u32/s32 parameters: large enough that
/// bounds-checked kernels keep most threads active and loop kernels
/// iterate a meaningful number of rounds, small enough to terminate
/// instantly.
const SCALAR: u64 = 64;

/// Memory image both sides start from: every u32 word is a fixed
/// pseudo-random value *bounded below 997*, so kernels that use loaded
/// data as an index (gather) stay comfortably inside [`MEM_BYTES`].
fn seeded_machine() -> Machine {
    let mut m = Machine::new(MEM_BYTES);
    let words: Vec<u32> =
        (0..(MEM_BYTES / 4) as u32).map(|i| i.wrapping_mul(2_654_435_761) % 997).collect();
    m.write_u32s(0, &words);
    m
}

/// Synthesize one argument per kernel parameter from its type: u64
/// params are treated as pointers and handed disjoint [`REGION`]-sized
/// areas, integer scalars get [`SCALAR`], f32 scalars get 1.5.
pub fn synth_args(k: &Kernel) -> Args {
    let mut ptrs = 0u64;
    k.params
        .iter()
        .map(|(_, ty)| match ty {
            Type::U64 => {
                ptrs += 1;
                ptrs * REGION as u64
            }
            Type::U32 | Type::S32 => SCALAR,
            Type::F32 => 1.5f32.to_bits() as u64,
            Type::Pred => 0,
        })
        .collect()
}

/// Differential check of `sliced` (a rectified form of `k`) against
/// `k` itself: compare a whole-grid launch of the original with
/// slice-by-slice launches of the rectified kernel (slice sizes 1, 2
/// and 3 blocks over two grid shapes). Returns the number of
/// (grid, slice-size) configurations compared; errors on the first
/// byte-level divergence. Exposed separately from [`verify_rectify`]
/// so tests can feed a deliberately broken transform and watch it
/// fail.
pub fn rectify_differential(k: &Kernel, sliced: &Kernel, dims: u32) -> Result<usize> {
    let args = synth_args(k);
    let init = seeded_machine();
    let grids: &[(u32, u32)] = if dims == 2 { &[(3, 2), (4, 4)] } else { &[(5, 1), (8, 1)] };
    let block = if dims == 2 { (4, 4) } else { (8, 1) };
    let mut compared = 0usize;
    for &grid in grids {
        // Reference: one full launch of the ORIGINAL kernel.
        let mut whole = init.clone();
        launch(k, LaunchConfig { grid, block }, &args, &mut whole)
            .with_context(|| format!("{}: reference launch grid {grid:?}", k.name))?;
        for slice_blocks in [1u32, 2, 3] {
            let mut m = init.clone();
            let total = grid.0 * grid.1;
            let mut next = 0u32;
            while next < total {
                let this = slice_blocks.min(total - next);
                let mut sargs = args.clone();
                if dims == 2 {
                    // Linearized offset; the rectifier's Fig. 3c wrap
                    // loop folds x-overflow into y.
                    sargs.extend([
                        (next % grid.0) as u64,
                        grid.0 as u64,
                        (next / grid.0) as u64,
                        grid.1 as u64,
                    ]);
                } else {
                    sargs.extend([next as u64, grid.0 as u64]);
                }
                launch(sliced, LaunchConfig { grid: (this, 1), block }, &sargs, &mut m)
                    .with_context(|| {
                        format!("{}: slice of {this} blocks at offset {next}", k.name)
                    })?;
                next += this;
            }
            if m.memory != whole.memory {
                let at =
                    m.memory.iter().zip(&whole.memory).position(|(a, b)| a != b).unwrap_or(0);
                bail!(
                    "{}: grid {grid:?}, slice {slice_blocks}: sliced memory diverges \
                     from the reference at byte {at}",
                    k.name
                );
            }
            compared += 1;
        }
    }
    Ok(compared)
}

/// Rectify `k` (dimensionality inferred from its special-register
/// reads) and differentially verify the transform. Returns the number
/// of configurations compared.
pub fn verify_rectify(k: &Kernel) -> Result<usize> {
    let dims = infer_dims(k);
    let opts = if dims == 2 { RectifyOptions::two_d() } else { RectifyOptions::one_d() };
    rectify_differential(k, &rectify(k, &opts), dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptx::ast::{BinOp, Inst, Operand, Special};
    use crate::ptx::parser::parse_kernel;
    use crate::ptx::samples;

    #[test]
    fn saxpy_and_matrix_add_verify() {
        for src in [samples::SAXPY, samples::MATRIX_ADD] {
            let k = parse_kernel(src).unwrap();
            let compared = verify_rectify(&k).unwrap();
            assert_eq!(compared, 6, "{}: 2 grids x 3 slice sizes", k.name);
        }
    }

    #[test]
    fn synthesized_pointers_are_disjoint_regions() {
        let k = parse_kernel(samples::GATHER).unwrap();
        let args = synth_args(&k);
        assert_eq!(args, vec![32 * 1024, 64 * 1024, 96 * 1024]);
    }

    #[test]
    fn tampered_transform_is_caught() {
        let k = parse_kernel(samples::SAXPY).unwrap();
        let mut bad = rectify(&k, &RectifyOptions::one_d());
        // Sabotage the prologue's index rebase: rx = off - ctaid
        // instead of off + ctaid. Slices of 1 block happen to survive
        // (ctaid is 0), so the multi-size sweep is what catches it.
        let rebase = bad
            .body
            .iter_mut()
            .find(|i| {
                matches!(
                    i,
                    Inst::Bin { op: BinOp::Add, b: Operand::Special(Special::CtaIdX), .. }
                )
            })
            .expect("rectified saxpy has the ctaid rebase add");
        if let Inst::Bin { op, .. } = rebase {
            *op = BinOp::Sub;
        }
        let err = rectify_differential(&k, &bad, 1).unwrap_err();
        assert!(err.to_string().contains("diverges"), "{err:#}");
    }
}
