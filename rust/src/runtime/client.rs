//! PJRT client wrapper and compiled-artifact registry.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// A concrete tensor value crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    /// f32 data plus dimensions.
    F32(Vec<f32>, Vec<i64>),
    /// i32 data plus dimensions.
    I32(Vec<i32>, Vec<i64>),
}

impl Tensor {
    /// Tensor dimensions.
    pub fn dims(&self) -> &[i64] {
        match self {
            Tensor::F32(_, d) | Tensor::I32(_, d) => d,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v, _) => v.len(),
            Tensor::I32(v, _) => v.len(),
        }
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether dtype and dims match a manifest spec.
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        let dt = match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
        };
        dt == spec.dtype && self.dims() == spec.dims.as_slice()
    }

    /// Borrow the f32 payload (errors on an i32 tensor).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Borrow the i32 payload (errors on an f32 tensor).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Tensor::F32(v, dims) => xla::Literal::vec1(v).reshape(dims)?,
            Tensor::I32(v, dims) => xla::Literal::vec1(v).reshape(dims)?,
        })
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        Ok(match spec.dtype {
            DType::F32 => Tensor::F32(lit.to_vec::<f32>()?, spec.dims.clone()),
            DType::I32 => Tensor::I32(lit.to_vec::<i32>()?, spec.dims.clone()),
        })
    }
}

/// Loads artifacts once, compiles once, executes many times — "one
/// compiled executable per model variant".
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl ArtifactRegistry {
    /// Open the registry over an artifact directory produced by
    /// `make artifacts`.
    pub fn open(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    /// Open at the default location.
    pub fn open_default() -> Result<Self> {
        Self::open(super::artifacts_dir())
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Find the artifact entry for (kernel, n_blocks).
    pub fn spec(&self, kernel: &str, n_blocks: u32) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .iter()
            .find(|a| a.kernel == kernel && a.n_blocks == n_blocks)
            .with_context(|| format!("no artifact for {kernel} nb={n_blocks}"))
    }

    /// Compile (cached) the artifact for (kernel, n_blocks).
    fn executable(&self, file: &str) -> Result<()> {
        let mut cache = self.compiled.lock().unwrap();
        if cache.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {file}"))?;
        cache.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute one artifact with the given inputs, validating shapes
    /// against the manifest.
    pub fn execute(&self, kernel: &str, n_blocks: u32, inputs: &[Tensor]) -> Result<Tensor> {
        let spec = self.spec(kernel, n_blocks)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{kernel} nb={n_blocks}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if !t.matches(s) {
                bail!("{kernel} nb={n_blocks}: input {i} mismatches manifest spec {s:?}");
            }
        }
        self.executable(&spec.file)?;
        let cache = self.compiled.lock().unwrap();
        let exe = cache.get(&spec.file).unwrap();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Tensor::from_literal(&out, &spec.output)
    }

    /// Number of distinct compiled executables so far.
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_matching() {
        let t = Tensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(t.matches(&TensorSpec { dtype: DType::F32, dims: vec![2, 3] }));
        assert!(!t.matches(&TensorSpec { dtype: DType::F32, dims: vec![3, 2] }));
        assert!(!t.matches(&TensorSpec { dtype: DType::I32, dims: vec![2, 3] }));
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::I32(vec![1, 2, 3], vec![3]);
        assert_eq!(t.len(), 3);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }

    // PJRT-backed tests live in tests/runtime_pjrt.rs and skip when
    // artifacts are absent.
}
