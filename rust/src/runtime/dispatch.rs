//! Sliced real-compute dispatch: run a kernel's grid as a sequence of
//! slice executions through PJRT and stitch the outputs.
//!
//! This is the real-numerics counterpart of the simulator's timing
//! model: the coordinator decides slice sizes; this module proves the
//! decision is *safe* by executing actual compiled kernels slice by
//! slice and verifying the stitched output equals the full-grid run.
//! [`PjrtBackend`] additionally plugs those executions into the
//! scheduling engine as a [`TimingBackend`], so the same dispatch loop
//! that runs on the simulator can be driven by real kernel launches.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::client::{ArtifactRegistry, Tensor};
use crate::config::GpuConfig;
use crate::coordinator::{PairTiming, TimingBackend};
use crate::kernel::KernelSpec;
use crate::stats::Xoshiro256;

/// Runs sliceable kernels through the artifact registry.
pub struct SlicedRunner<'a> {
    reg: &'a ArtifactRegistry,
}

impl<'a> SlicedRunner<'a> {
    /// A runner over the registry's loaded artifacts.
    pub fn new(reg: &'a ArtifactRegistry) -> Self {
        Self { reg }
    }

    /// Total grid blocks of a kernel = the largest AOT variant.
    pub fn total_blocks(&self, kernel: &str) -> Result<u32> {
        self.reg
            .manifest()
            .variants(kernel)
            .first()
            .map(|a| a.n_blocks)
            .context("unknown kernel")
    }

    /// Execute the full grid in one launch (offset 0).
    pub fn run_full(&self, kernel: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let nb = self.total_blocks(kernel)?;
        let args = with_offset(inputs, 0);
        self.reg.execute(kernel, nb, &args)
    }

    /// Execute the grid as contiguous slices of the given block counts
    /// (must partition the grid and match AOT'd variants), stitching
    /// outputs along axis 0.
    pub fn run_sliced(&self, kernel: &str, inputs: &[Tensor], slice_blocks: &[u32]) -> Result<Tensor> {
        let total = self.total_blocks(kernel)?;
        if slice_blocks.iter().sum::<u32>() != total {
            bail!("slices {slice_blocks:?} do not partition {total} blocks");
        }
        let mut offset = 0u32;
        let mut pieces: Vec<Tensor> = Vec::new();
        for &nb in slice_blocks {
            let args = with_offset(inputs, offset as i32);
            pieces.push(self.reg.execute(kernel, nb, &args)?);
            offset += nb;
        }
        concat0(&pieces)
    }

    /// Run full and sliced, verify bit-identical, return (output,
    /// max abs diff == 0). The E2E driver calls this per request.
    pub fn run_verified(&self, kernel: &str, inputs: &[Tensor], slice_blocks: &[u32]) -> Result<Tensor> {
        let full = self.run_full(kernel, inputs)?;
        let sliced = self.run_sliced(kernel, inputs, slice_blocks)?;
        if full != sliced {
            bail!("{kernel}: sliced execution diverged from full run");
        }
        Ok(full)
    }

    /// Random example inputs matching the manifest spec of a kernel
    /// (offset excluded). Mirrors `example_inputs` on the python side
    /// in distribution, not values — the verification is
    /// self-consistency, the oracle check lives in pytest.
    pub fn example_inputs(&self, kernel: &str, seed: u64) -> Result<Vec<Tensor>> {
        let nb = self.total_blocks(kernel)?;
        let spec = self.reg.spec(kernel, nb)?;
        let mut rng = Xoshiro256::new(seed);
        let mut out = Vec::new();
        for ts in spec.inputs.iter().skip(1) {
            // skip the offset arg
            let n = ts.elements();
            out.push(match ts.dtype {
                super::manifest::DType::F32 => Tensor::F32(
                    (0..n).map(|_| rng.range_f64(0.1, 2.0) as f32).collect(),
                    ts.dims.clone(),
                ),
                super::manifest::DType::I32 => {
                    // Index-like inputs must stay in-range; the largest
                    // safe bound for every int input in the suite is the
                    // smallest dimension product of any f32 input ---
                    // conservatively use n for permutation-ish data.
                    let bound = index_bound(kernel, ts, spec);
                    Tensor::I32(
                        (0..n).map(|_| rng.below(bound as u64) as i32).collect(),
                        ts.dims.clone(),
                    )
                }
            });
        }
        Ok(out)
    }
}

/// Safe upper bound for integer inputs (they are gather indices in
/// pc/spmv, arbitrary payload in tea).
fn index_bound(kernel: &str, _ts: &super::manifest::TensorSpec, spec: &super::manifest::ArtifactSpec) -> i64 {
    match kernel {
        // pc: idx indexes into data (second f32 input).
        "pc" => spec.inputs.iter().skip(1).find_map(|t| {
            (t.dtype == super::manifest::DType::F32).then(|| t.elements() as i64)
        }).unwrap_or(1),
        // spmv: idx indexes into x (the 1-D f32 input).
        "spmv" => spec
            .inputs
            .iter()
            .filter(|t| t.dtype == super::manifest::DType::F32 && t.dims.len() == 1)
            .map(|t| t.elements() as i64)
            .min()
            .unwrap_or(1),
        // tea and friends: full i32 range is fine, but keep it modest.
        _ => i32::MAX as i64 / 2,
    }
}

fn with_offset(inputs: &[Tensor], offset: i32) -> Vec<Tensor> {
    let mut args = Vec::with_capacity(inputs.len() + 1);
    args.push(Tensor::I32(vec![offset], vec![1]));
    args.extend(inputs.iter().cloned());
    args
}

/// Concatenate tensors along axis 0.
fn concat0(pieces: &[Tensor]) -> Result<Tensor> {
    if pieces.is_empty() {
        bail!("nothing to concatenate");
    }
    let tail_dims = pieces[0].dims()[1..].to_vec();
    let mut rows = 0i64;
    for p in pieces {
        if p.dims()[1..] != tail_dims[..] {
            bail!("ragged concatenation");
        }
        rows += p.dims()[0];
    }
    let mut dims = vec![rows];
    dims.extend(&tail_dims);
    Ok(match &pieces[0] {
        Tensor::F32(..) => {
            let mut v = Vec::new();
            for p in pieces {
                v.extend_from_slice(p.as_f32()?);
            }
            Tensor::F32(v, dims)
        }
        Tensor::I32(..) => {
            let mut v = Vec::new();
            for p in pieces {
                v.extend_from_slice(p.as_i32()?);
            }
            Tensor::I32(v, dims)
        }
    })
}

/// Real-compute timing backend for the scheduling engine: slice
/// durations come from executing the AOT-compiled artifact through
/// PJRT and converting measured host wall-clock into "GPU cycles" at
/// the config's clock rate. Kernels without an AOT artifact (and any
/// execution error) fall back to the wrapped backend, so mixed streams
/// still schedule.
///
/// Two approximations, by construction of the testbed: requested block
/// counts are scaled linearly from the nearest AOT'd slice variant, and
/// the PJRT CPU client has no co-residency, so a pair round costs the
/// sum of its two slices. Wall-clock measurements are inherently
/// nondeterministic — use the simulator backend where reproducibility
/// matters (figures, differential tests).
pub struct PjrtBackend<'a> {
    reg: &'a ArtifactRegistry,
    runner: SlicedRunner<'a>,
    gpu: GpuConfig,
    fallback: &'a dyn TimingBackend,
    /// Ready argument vectors (offset 0 prepended) per artifact
    /// kernel, built once — input synthesis must not pollute the
    /// timing, and a synthesis failure is cached as `None` so it is
    /// not retried on every slice.
    args: Mutex<HashMap<String, Option<Arc<Vec<Tensor>>>>>,
    /// (kernel, n_blocks) variants already executed once: the registry
    /// compiles lazily on first use, and compile time must not pollute
    /// the timing either.
    warmed: Mutex<std::collections::HashSet<(String, u32)>>,
}

impl<'a> PjrtBackend<'a> {
    /// A timing backend executing slices through `reg`, modeling `gpu`
    /// and deferring to `fallback` for kernels without artifacts.
    pub fn new(reg: &'a ArtifactRegistry, gpu: &GpuConfig, fallback: &'a dyn TimingBackend) -> Self {
        Self {
            reg,
            runner: SlicedRunner::new(reg),
            gpu: gpu.clone(),
            fallback,
            args: Mutex::new(HashMap::new()),
            warmed: Mutex::new(std::collections::HashSet::new()),
        }
    }

    /// Artifact name for a benchmark spec ("PC" → "pc"), if AOT'd.
    fn artifact_for(&self, spec_name: &str) -> Option<String> {
        let name = spec_name.to_ascii_lowercase();
        if self.reg.manifest().variants(&name).is_empty() {
            None
        } else {
            Some(name)
        }
    }

    /// Wall-seconds to execute `blocks` blocks of `kernel` as one
    /// slice, scaled linearly from the nearest AOT'd variant.
    fn measure_slice_secs(&self, kernel: &str, blocks: u32) -> Option<f64> {
        let variants = self.reg.manifest().variants(kernel);
        let v = variants
            .iter()
            .filter(|a| a.n_blocks <= blocks)
            .max_by_key(|a| a.n_blocks)
            .or_else(|| variants.iter().min_by_key(|a| a.n_blocks))?;
        let nb = v.n_blocks;
        let args: Arc<Vec<Tensor>> = {
            let mut map = self.args.lock().unwrap();
            map.entry(kernel.to_string())
                .or_insert_with(|| {
                    self.runner
                        .example_inputs(kernel, 0xCAFE)
                        .ok()
                        .map(|ins| Arc::new(with_offset(&ins, 0)))
                })
                .clone()?
        };
        // First use of a variant compiles the executable lazily inside
        // the registry; run it once untimed so the measurement below
        // sees execution only. Mark it warmed only after that run
        // succeeds, so a transient failure does not skip future
        // warm-ups and leak compile time into the clock.
        let needs_warm = !self.warmed.lock().unwrap().contains(&(kernel.to_string(), nb));
        if needs_warm {
            self.reg.execute(kernel, nb, &args).ok()?;
            self.warmed.lock().unwrap().insert((kernel.to_string(), nb));
        }
        let t0 = Instant::now();
        self.reg.execute(kernel, nb, &args).ok()?;
        let dt = t0.elapsed().as_secs_f64();
        Some(dt * blocks as f64 / nb as f64)
    }
}

impl TimingBackend for PjrtBackend<'_> {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn time_solo(&self, spec: &KernelSpec, blocks: u32) -> f64 {
        if let Some(k) = self.artifact_for(spec.name) {
            if let Some(secs) = self.measure_slice_secs(&k, blocks) {
                return secs * self.gpu.clock_hz();
            }
        }
        self.fallback.time_solo(spec, blocks)
    }

    fn time_pair(
        &self,
        k1: &KernelSpec,
        s1: u32,
        q1: u32,
        k2: &KernelSpec,
        s2: u32,
        q2: u32,
    ) -> PairTiming {
        if let (Some(a), Some(b)) = (self.artifact_for(k1.name), self.artifact_for(k2.name)) {
            if let (Some(t1), Some(t2)) =
                (self.measure_slice_secs(&a, s1), self.measure_slice_secs(&b, s2))
            {
                let cycles = ((t1 + t2) * self.gpu.clock_hz()).max(1e-9);
                let cipc = [
                    k1.inst_per_block(&self.gpu) as f64 * s1 as f64 / cycles,
                    k2.inst_per_block(&self.gpu) as f64 * s2 as f64 / cycles,
                ];
                return PairTiming { cycles, cipc, total_ipc: cipc[0] + cipc[1] };
            }
        }
        self.fallback.time_pair(k1, s1, q1, k2, s2, q2)
    }
}

/// Steady-state evaluation through the AOT markov artifact: pads the
/// chain to the artifact's fixed frame and returns the active-state
/// distribution. The PJRT-vs-native agreement test lives in
/// `tests/runtime_pjrt.rs`.
pub fn steady_state_pjrt(reg: &ArtifactRegistry, p_small: &[Vec<f64>]) -> Result<Vec<f64>> {
    const PAD: usize = 64;
    let n = p_small.len();
    if n > PAD {
        bail!("chain of {n} states exceeds the AOT frame ({PAD})");
    }
    let mut p = vec![0f32; PAD * PAD];
    for i in 0..PAD {
        p[i * PAD + i] = 1.0; // identity padding rows
    }
    for (i, row) in p_small.iter().enumerate() {
        if row.len() != n {
            bail!("ragged transition matrix");
        }
        for (j, &v) in row.iter().enumerate() {
            p[i * PAD + j] = v as f32;
        }
        p[i * PAD + i] = row[i] as f32; // overwrite identity diag
    }
    let mut pi0 = vec![0f32; PAD];
    for v in pi0.iter_mut().take(n) {
        *v = 1.0 / n as f32;
    }
    let out = reg.execute(
        "markov_steady",
        1,
        &[
            Tensor::F32(p, vec![PAD as i64, PAD as i64]),
            Tensor::F32(pi0, vec![PAD as i64]),
        ],
    )?;
    Ok(out.as_f32()?[..n].iter().map(|&x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat0_f32() {
        let a = Tensor::F32(vec![1.0, 2.0], vec![1, 2]);
        let b = Tensor::F32(vec![3.0, 4.0, 5.0, 6.0], vec![2, 2]);
        let c = concat0(&[a, b]).unwrap();
        assert_eq!(c.dims(), &[3, 2]);
        assert_eq!(c.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn concat0_rejects_ragged() {
        let a = Tensor::F32(vec![1.0, 2.0], vec![1, 2]);
        let b = Tensor::F32(vec![3.0], vec![1, 1]);
        assert!(concat0(&[a, b]).is_err());
    }

    #[test]
    fn with_offset_prepends() {
        let args = with_offset(&[Tensor::F32(vec![1.0], vec![1])], 5);
        assert_eq!(args.len(), 2);
        assert_eq!(args[0], Tensor::I32(vec![5], vec![1]));
    }
}
