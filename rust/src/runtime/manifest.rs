//! Artifact manifest parsing.
//!
//! `aot.py` writes one line per artifact:
//! `file|kernel|n_blocks|in:<dtype>:<dims>,...|out:<dtype>:<dims>`
//! e.g. `mm_nb4.hlo.txt|mm|4|in:int32:1,float32:128x64,float32:64x64|out:float32:64x64`.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of a tensor argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    /// Parse a manifest dtype name (`float32`/`f32`, `int32`/`i32`).
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// Shape + dtype of one argument or result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions (empty = scalar).
    pub dims: Vec<i64>,
}

impl TensorSpec {
    /// Parse a `dtype:AxBxC` (or `dtype:scalar`) manifest spec.
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (d, dims) = s.split_once(':').with_context(|| format!("bad tensor spec {s}"))?;
        let dtype = DType::parse(d)?;
        let dims = if dims == "scalar" {
            vec![]
        } else {
            dims.split('x')
                .map(|x| x.parse::<i64>().with_context(|| format!("bad dim in {s}")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Compiled artifact file name.
    pub file: String,
    /// Kernel the artifact implements.
    pub kernel: String,
    /// Grid blocks this variant covers.
    pub n_blocks: u32,
    /// Input tensor shapes.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor shape.
    pub output: TensorSpec,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Artifact entries in file order.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse the `manifest.txt` format (one artifact per line).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 5 {
                bail!("manifest line {} malformed: {line}", lineno + 1);
            }
            let ins = parts[3]
                .strip_prefix("in:")
                .with_context(|| format!("line {}: missing in:", lineno + 1))?;
            let out = parts[4]
                .strip_prefix("out:")
                .with_context(|| format!("line {}: missing out:", lineno + 1))?;
            artifacts.push(ArtifactSpec {
                file: parts[0].to_string(),
                kernel: parts[1].to_string(),
                n_blocks: parts[2].parse().context("n_blocks")?,
                inputs: ins.split(',').map(TensorSpec::parse).collect::<Result<Vec<_>>>()?,
                output: TensorSpec::parse(out)?,
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt", dir.display()))?;
        Self::parse(&text)
    }

    /// All entries for one kernel, sorted by descending block count.
    pub fn variants(&self, kernel: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<_> = self.artifacts.iter().filter(|a| a.kernel == kernel).collect();
        v.sort_by(|a, b| b.n_blocks.cmp(&a.n_blocks));
        v
    }

    /// Kernel names present (excluding the markov solver).
    pub fn kernels(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .artifacts
            .iter()
            .map(|a| a.kernel.clone())
            .filter(|k| k != "markov_steady")
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
mm_nb8.hlo.txt|mm|8|in:int32:1,float32:128x64,float32:64x64|out:float32:128x64
mm_nb4.hlo.txt|mm|4|in:int32:1,float32:128x64,float32:64x64|out:float32:64x64
markov_steady.hlo.txt|markov_steady|1|in:float32:64x64,float32:64|out:float32:64
#markov_pad=64 markov_iters=256
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let mm8 = &m.artifacts[0];
        assert_eq!(mm8.kernel, "mm");
        assert_eq!(mm8.n_blocks, 8);
        assert_eq!(mm8.inputs.len(), 3);
        assert_eq!(mm8.inputs[0], TensorSpec { dtype: DType::I32, dims: vec![1] });
        assert_eq!(mm8.output.dims, vec![128, 64]);
    }

    #[test]
    fn variants_sorted_desc() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.variants("mm");
        assert_eq!(v.len(), 2);
        assert!(v[0].n_blocks > v[1].n_blocks);
    }

    #[test]
    fn kernels_excludes_markov() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.kernels(), vec!["mm".to_string()]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("only|three|fields").is_err());
        assert!(Manifest::parse("f|k|x|in:f32:1|out:f32:1").is_err()); // bad n_blocks
        assert!(Manifest::parse("f|k|1|in:f99:1|out:f32:1").is_err()); // bad dtype
    }

    #[test]
    fn scalar_dims() {
        let t = TensorSpec::parse("float32:scalar").unwrap();
        assert!(t.dims.is_empty());
        assert_eq!(t.elements(), 1);
    }
}
