//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! Python runs once at build time (`make artifacts`): JAX lowers every
//! sliceable Pallas kernel variant and the Markov steady-state solver
//! to HLO text (see `python/compile/aot.py`). This module is the
//! request-path side: the rust coordinator loads the text with
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and executes slices with concrete inputs — Python is never
//! on the request path. [`PjrtBackend`] exposes those executions to the
//! scheduling engine as a `TimingBackend`, so the coordinator's one
//! dispatch loop can run on real compute instead of the simulator.

//! The PJRT execution path (`client`, `dispatch`) is gated behind the
//! `pjrt` cargo feature: the `xla` binding needs the native XLA
//! extension library at build time, which CI machines and offline
//! containers don't have. The manifest parser and artifact discovery
//! stay available either way so tooling can inspect artifacts without
//! the heavy dependency.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod dispatch;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{ArtifactRegistry, Tensor};
#[cfg(feature = "pjrt")]
pub use dispatch::{PjrtBackend, SlicedRunner};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use std::path::{Path, PathBuf};

/// Default artifact directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$KERNELET_ARTIFACTS`, else
/// `artifacts/` relative to the crate root, else the current dir.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("KERNELET_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join(ARTIFACTS_DIR);
    if manifest_dir.exists() {
        return manifest_dir;
    }
    PathBuf::from(ARTIFACTS_DIR)
}

/// True when `make artifacts` has produced a manifest (integration
/// tests skip politely when it hasn't).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
