//! Sharded, read-optimized concurrent maps for the scheduler's hot-path
//! caches.
//!
//! The seed guarded every memoization table (`SimCache`, the greedy
//! model caches, `SliceSizeCache`) with a single `Mutex<HashMap>`, so
//! `prewarm_pairs`/`prewarm_solo` worker threads and per-device engines
//! serialized on one lock — and the warm path (a pure read) paid a
//! writer lock per probe. [`ShardedMap`] splits the key space over
//! `N` independent `RwLock<HashMap>` shards (key-hash → shard), so
//! readers on different shards never touch the same lock and readers on
//! the *same* shard share it. Hit/miss telemetry moves to
//! [`CacheCounters`] (two `AtomicU64`s) instead of two more mutexes per
//! lookup.
//!
//! Values are returned by clone; cached entries are small `Copy`-ish
//! measurement records. Concurrent fill of the same key is benign: the
//! backing computations are deterministic, so the last writer stores
//! the same value the first did.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Default shard count. Power of two so the hash can be masked; 16 is
/// comfortably past the thread counts `prewarm_*` spawns.
pub const DEFAULT_SHARDS: usize = 16;

/// A concurrent hash map split into power-of-two lock shards.
pub struct ShardedMap<K, V> {
    shards: Vec<RwLock<HashMap<K, V>>>,
    mask: usize,
}

impl<K: Hash + Eq, V: Clone> ShardedMap<K, V> {
    /// A map with the default shard count.
    pub fn new() -> Self {
        Self::with_shards(DEFAULT_SHARDS)
    }

    /// A map with `n` lock shards (rounded up to at least 1).
    pub fn with_shards(n: usize) -> Self {
        let n = n.max(1).next_power_of_two();
        Self { shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(), mask: n - 1 }
    }

    fn shard<Q>(&self, key: &Q) -> &RwLock<HashMap<K, V>>
    where
        Q: Hash + ?Sized,
    {
        // DefaultHasher::new() uses fixed keys: shard placement is
        // deterministic across runs (only placement — results never
        // depend on it). The `Borrow` contract guarantees a borrowed
        // form hashes identically to the owned key, so lookups land on
        // the shard the insert chose.
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) & self.mask]
    }

    /// Read a value (shared lock on one shard only). Accepts any
    /// borrowed form of the key, like [`HashMap::get`] — so a `&str`
    /// probes a `String`-keyed map without allocating on the hit path.
    pub fn get<Q>(&self, key: &Q) -> Option<V>
    where
        K: std::borrow::Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        self.shard(key).read().unwrap().get(key).cloned()
    }

    /// Insert a value (exclusive lock on one shard only).
    pub fn insert(&self, key: K, value: V) {
        self.shard(&key).write().unwrap().insert(key, value);
    }

    /// Clone every entry out of the map (each shard's read lock taken
    /// in turn — a point-in-time view per shard, not a global one).
    /// Order is unspecified (shard + `HashMap` iteration order);
    /// callers wanting determinism sort the result. Built for the
    /// cache-persistence layer, which snapshots, sorts, and serializes.
    pub fn snapshot(&self) -> Vec<(K, V)>
    where
        K: Clone,
    {
        let mut out = Vec::with_capacity(self.len());
        for s in &self.shards {
            let g = s.read().unwrap();
            out.extend(g.iter().map(|(k, v)| (k.clone(), v.clone())));
        }
        out
    }

    /// Copy every entry of `other` into this map (existing keys are
    /// overwritten — with deterministic fills both sides hold the same
    /// value anyway). Returns the number of entries copied. This is the
    /// substrate of cache *sharing*: sweeps that build one dispatcher
    /// per cell seed each fresh cache from a prewarmed donor instead of
    /// re-simulating the same cells per policy.
    pub fn absorb(&self, other: &Self) -> usize
    where
        K: Clone,
    {
        let entries = other.snapshot();
        let n = entries.len();
        for (k, v) in entries {
            self.insert(k, v);
        }
        n
    }

    /// Total entries across shards (telemetry; takes each read lock in
    /// turn, so the count is only a snapshot under concurrency).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Whether every shard is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: Hash + Eq, V: Clone> Default for ShardedMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Lock-free hit/miss counters for a cache.
#[derive(Debug, Default)]
pub struct CacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheCounters {
    /// Zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    /// Record a cache hit.
    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    /// Record a cache miss.
    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// (hits, misses) snapshot.
    pub fn snapshot(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_insert_roundtrip() {
        let m: ShardedMap<(String, u32), f64> = ShardedMap::new();
        assert!(m.get(&("a".to_string(), 1)).is_none());
        m.insert(("a".to_string(), 1), 2.5);
        m.insert(("b".to_string(), 2), 3.5);
        assert_eq!(m.get(&("a".to_string(), 1)), Some(2.5));
        assert_eq!(m.get(&("b".to_string(), 2)), Some(3.5));
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn borrowed_key_lookup() {
        let m: ShardedMap<String, u32> = ShardedMap::new();
        m.insert("alpha".to_string(), 7);
        // &str probes a String-keyed map (no allocation on the hit
        // path) and must land on the shard the insert chose.
        assert_eq!(m.get("alpha"), Some(7));
        assert_eq!(m.get("beta"), None);
    }

    #[test]
    fn absorb_copies_all_entries() {
        let a: ShardedMap<u64, u64> = ShardedMap::new();
        let b: ShardedMap<u64, u64> = ShardedMap::new();
        for k in 0..32u64 {
            a.insert(k, k * 3);
        }
        b.insert(1, 3); // overlapping key, same deterministic value
        assert_eq!(b.absorb(&a), 32);
        assert_eq!(b.len(), 32);
        for k in 0..32u64 {
            assert_eq!(b.get(&k), Some(k * 3));
        }
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let m: ShardedMap<u64, u64> = ShardedMap::with_shards(5);
        assert_eq!(m.shards.len(), 8);
        let m: ShardedMap<u64, u64> = ShardedMap::with_shards(0);
        assert_eq!(m.shards.len(), 1);
    }

    #[test]
    fn keys_spread_across_shards() {
        let m: ShardedMap<u64, u64> = ShardedMap::with_shards(16);
        for k in 0..256u64 {
            m.insert(k, k);
        }
        let occupied = m.shards.iter().filter(|s| !s.read().unwrap().is_empty()).count();
        assert!(occupied >= 8, "only {occupied}/16 shards used");
        assert_eq!(m.len(), 256);
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let m: ShardedMap<u64, u64> = ShardedMap::new();
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let m = &m;
                scope.spawn(move || {
                    for i in 0..200u64 {
                        let k = t * 1000 + i;
                        m.insert(k, k * 2);
                        assert_eq!(m.get(&k), Some(k * 2));
                    }
                });
            }
        });
        assert_eq!(m.len(), 8 * 200);
    }

    #[test]
    fn counters_are_atomic() {
        let c = CacheCounters::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = &c;
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.hit();
                    }
                    for _ in 0..500 {
                        c.miss();
                    }
                });
            }
        });
        assert_eq!(c.snapshot(), (4000, 2000));
    }
}
