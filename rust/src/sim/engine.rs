//! The per-SM execution engine.
//!
//! Event-driven at warp granularity: warps sit either in a FIFO ready
//! ring (served round-robin, like the hardware warp schedulers polling
//! ready warps each round — paper §4.4) or in per-source sorted wake-up
//! FIFOs keyed by the cycle their outstanding dependency resolves (see
//! the §Perf note on `SmEngine`). Issue bandwidth is a fractional
//! per-cycle budget (`peak_ipc`), so Fermi's half-warp-per-scheduler
//! issue and Kepler's dual issue both map onto the same mechanism.

use std::collections::VecDeque;

use super::memory::MemoryPipe;
use super::metrics::{KernelMetrics, SimResult};
use crate::config::GpuConfig;
use crate::kernel::KernelSpec;
use crate::stats::Xoshiro256;

/// A kernel plus the number of its blocks assigned to this SM.
#[derive(Debug, Clone)]
pub struct Workload {
    /// The kernel being simulated.
    pub spec: KernelSpec,
    /// Blocks of it assigned to this SM.
    pub blocks: u32,
    /// Residency quota: at most this many blocks of this workload may
    /// be co-resident on the SM. This is how a co-schedule's (b1, b2)
    /// split pins each slice's occupancy (the paper's "slices with
    /// tunable occupancy") — without it, a kernel with tiny blocks
    /// slowly steals every freed block slot from its partner.
    pub quota: Option<u32>,
}

impl Workload {
    /// An unquota'd workload of `blocks` blocks.
    pub fn new(spec: KernelSpec, blocks: u32) -> Self {
        assert!(blocks >= 1, "workload with zero blocks");
        Self { spec, blocks, quota: None }
    }

    /// A workload capped at `quota` co-resident blocks.
    pub fn with_quota(spec: KernelSpec, blocks: u32, quota: u32) -> Self {
        assert!(blocks >= 1 && quota >= 1);
        Self { spec, blocks, quota: Some(quota) }
    }
}

/// One resident warp's execution state.
#[derive(Debug, Clone)]
struct WarpState {
    /// Index into the engine's workload list.
    kernel: usize,
    /// Resident-block slot this warp belongs to.
    block_slot: usize,
    /// Instructions left in the current block assignment.
    remaining: u32,
}

/// A resident-block slot: tracks how many of its warps have finished.
#[derive(Debug, Clone)]
struct BlockSlot {
    warps_left: u32,
    live: bool,
}

/// Resource accounting for block co-residency on the SM.
#[derive(Debug, Clone, Default)]
struct SmResources {
    threads: u32,
    regs: u32,
    smem: u32,
    blocks: u32,
    warps: u32,
}

impl SmResources {
    fn fits(&self, gpu: &GpuConfig, k: &KernelSpec) -> bool {
        let warps = k.threads_per_block.div_ceil(gpu.warp_size);
        self.threads + k.threads_per_block <= gpu.max_threads_per_sm
            && self.regs + k.regs_per_thread * k.threads_per_block <= gpu.regs_per_sm
            && self.smem + k.smem_per_block <= gpu.smem_per_sm
            && self.blocks + 1 <= gpu.max_blocks_per_sm
            && self.warps + warps <= gpu.max_warps_per_sm
    }

    fn claim(&mut self, gpu: &GpuConfig, k: &KernelSpec) {
        self.threads += k.threads_per_block;
        self.regs += k.regs_per_thread * k.threads_per_block;
        self.smem += k.smem_per_block;
        self.blocks += 1;
        self.warps += k.threads_per_block.div_ceil(gpu.warp_size);
    }

    fn release(&mut self, gpu: &GpuConfig, k: &KernelSpec) {
        self.threads -= k.threads_per_block;
        self.regs -= k.regs_per_thread * k.threads_per_block;
        self.smem -= k.smem_per_block;
        self.blocks -= 1;
        self.warps -= k.threads_per_block.div_ceil(gpu.warp_size);
    }
}

/// The engine simulating one representative SM.
///
/// Wake-up bookkeeping uses per-source sorted FIFOs instead of a heap
/// (§Perf: the heap's sift operations were 64% of Fig. 13 wall time).
/// Sortedness is structural: each workload's arithmetic stalls have a
/// constant gap, so `now + gap` is nondecreasing as `now` advances; and
/// the memory pipe's completion times are nondecreasing because its
/// bandwidth server frees monotonically and the pipeline latency is
/// constant.
pub struct SmEngine {
    gpu: GpuConfig,
    rng: Xoshiro256,
    workloads: Vec<Workload>,
    /// Blocks of each workload not yet made resident.
    pending_blocks: Vec<u32>,
    /// Blocks of each workload currently resident.
    resident_blocks: Vec<u32>,
    warps: Vec<WarpState>,
    /// Free warp-state indices for reuse.
    free_warps: Vec<usize>,
    slots: Vec<BlockSlot>,
    free_slots: Vec<usize>,
    resources: SmResources,
    /// Warps ready to issue, round-robin ring.
    ready: VecDeque<usize>,
    /// Warps stalled on arithmetic dependencies, one sorted FIFO per
    /// workload (constant gap per workload keeps each sorted).
    arith_sleep: Vec<VecDeque<(f64, usize)>>,
    /// Emptied per-workload sleep FIFOs parked between [`SmEngine::reset`]s
    /// so their ring buffers keep their capacity across engine reuse.
    spare_arith: Vec<VecDeque<(f64, usize)>>,
    /// Warps stalled on memory, one shared sorted FIFO (the pipe's
    /// completion times are nondecreasing).
    mem_sleep: VecDeque<(f64, usize)>,
    memory: MemoryPipe,
    metrics: Vec<KernelMetrics>,
    /// Round-robin cursor for refilling from multiple workloads.
    refill_cursor: usize,
}

impl SmEngine {
    /// An empty SM simulator for `gpu`, seeded deterministically.
    pub fn new(gpu: &GpuConfig, seed: u64) -> Self {
        Self {
            gpu: gpu.clone(),
            rng: Xoshiro256::new(seed),
            workloads: Vec::new(),
            pending_blocks: Vec::new(),
            resident_blocks: Vec::new(),
            warps: Vec::new(),
            free_warps: Vec::new(),
            slots: Vec::new(),
            free_slots: Vec::new(),
            resources: SmResources::default(),
            ready: VecDeque::new(),
            arith_sleep: Vec::new(),
            spare_arith: Vec::new(),
            mem_sleep: VecDeque::new(),
            memory: MemoryPipe::new(gpu),
            metrics: Vec::new(),
            refill_cursor: 0,
        }
    }

    /// Reset to the state [`SmEngine::new`] would produce for
    /// `(gpu, seed)` while keeping every internal buffer's allocated
    /// capacity. The cold path re-runs thousands of short simulations
    /// back to back (slice probes, pair rounds); reusing one engine via
    /// [`super::SimScratch`] removes their per-run allocations, and the
    /// results stay bitwise identical to a fresh engine because every
    /// piece of run state — RNG, memory pipe, cursors, counters — is
    /// reinitialized exactly as `new` does.
    pub fn reset(&mut self, gpu: &GpuConfig, seed: u64) {
        self.gpu.clone_from(gpu);
        self.rng = Xoshiro256::new(seed);
        self.workloads.clear();
        self.pending_blocks.clear();
        self.resident_blocks.clear();
        self.warps.clear();
        self.free_warps.clear();
        self.slots.clear();
        self.free_slots.clear();
        self.resources = SmResources::default();
        self.ready.clear();
        while let Some(mut q) = self.arith_sleep.pop() {
            q.clear();
            self.spare_arith.push(q);
        }
        self.mem_sleep.clear();
        self.memory = MemoryPipe::new(gpu);
        self.metrics.clear();
        self.refill_cursor = 0;
    }

    /// Register a workload before `run`. The first workload registered
    /// gets priority when blocks compete for SM residency (launch
    /// order, like the hardware dispatcher).
    pub fn add_workload(&mut self, w: Workload) {
        w.spec.validate();
        self.pending_blocks.push(w.blocks);
        self.resident_blocks.push(0);
        self.metrics.push(KernelMetrics::default());
        self.arith_sleep.push(self.spare_arith.pop().unwrap_or_default());
        self.workloads.push(w);
    }

    /// Earliest pending wake-up across every sleep queue.
    fn next_wake(&self) -> Option<f64> {
        let mut best: Option<f64> = self.mem_sleep.front().map(|&(at, _)| at);
        for q in &self.arith_sleep {
            if let Some(&(at, _)) = q.front() {
                best = Some(best.map_or(at, |b| b.min(at)));
            }
        }
        best
    }

    /// Move every warp due by `now` to the ready ring.
    // lint: no-alloc
    fn wake_due(&mut self, now: f64) {
        while let Some(&(at, w)) = self.mem_sleep.front() {
            if at <= now {
                self.mem_sleep.pop_front();
                self.ready.push_back(w);
            } else {
                break;
            }
        }
        for q in &mut self.arith_sleep {
            while let Some(&(at, w)) = q.front() {
                if at <= now {
                    q.pop_front();
                    self.ready.push_back(w);
                } else {
                    break;
                }
            }
        }
    }

    /// Try to make pending blocks resident while resources allow.
    /// Round-robin over workloads starting at `refill_cursor` so two
    /// co-scheduled kernels interleave their residency fairly (this is
    /// what slice-size tuning controls occupancy *through*).
    // lint: no-alloc
    fn refill(&mut self) {
        let n = self.workloads.len();
        if n == 0 {
            return;
        }
        // A quota only binds while some OTHER workload still has work:
        // once the partner slice drains, the hardware block dispatcher
        // lets the survivor expand into the freed slots. A workload's
        // activity cannot change inside this loop (admitting a block
        // moves it pending→resident, never to drained), so one count
        // up front replaces the seed's per-workload `Vec<bool>` — this
        // runs on every block completion.
        let mut total_active = 0usize;
        for j in 0..n {
            if self.pending_blocks[j] > 0 || self.resident_blocks[j] > 0 {
                total_active += 1;
            }
        }
        let mut stalled = 0usize;
        let mut i = self.refill_cursor % n;
        while stalled < n {
            let self_active = self.pending_blocks[i] > 0 || self.resident_blocks[i] > 0;
            let others_active = total_active - usize::from(self_active) > 0;
            let under_quota = !others_active
                || self.workloads[i]
                    .quota
                    .map_or(true, |q| self.resident_blocks[i] < q);
            if self.pending_blocks[i] > 0
                && under_quota
                && self.resources.fits(&self.gpu, &self.workloads[i].spec)
            {
                self.admit_block(i);
                stalled = 0;
            } else {
                stalled += 1;
            }
            i = (i + 1) % n;
        }
        self.refill_cursor = i;
    }

    fn admit_block(&mut self, kernel: usize) {
        let spec = self.workloads[kernel].spec.clone();
        self.resources.claim(&self.gpu, &spec);
        self.pending_blocks[kernel] -= 1;
        self.resident_blocks[kernel] += 1;
        let warps_per_block = spec.threads_per_block.div_ceil(self.gpu.warp_size);
        let slot = if let Some(s) = self.free_slots.pop() {
            self.slots[s] = BlockSlot { warps_left: warps_per_block, live: true };
            s
        } else {
            self.slots.push(BlockSlot { warps_left: warps_per_block, live: true });
            self.slots.len() - 1
        };
        for _ in 0..warps_per_block {
            let state = WarpState { kernel, block_slot: slot, remaining: spec.inst_per_warp };
            let w = if let Some(w) = self.free_warps.pop() {
                self.warps[w] = state;
                w
            } else {
                self.warps.push(state);
                self.warps.len() - 1
            };
            self.ready.push_back(w);
        }
    }

    /// Run until every workload's blocks have completed. Returns the
    /// accumulated metrics; `cycles` does NOT include launch overhead
    /// (callers add it — see [`super::simulate_solo`]).
    // lint: no-alloc
    pub fn run(&mut self) -> SimResult {
        assert!(!self.workloads.is_empty(), "no workloads");
        self.refill();
        let mut now = 0.0f64;
        // Fractional issue budget accumulated per cycle.
        let peak = self.gpu.peak_ipc();
        let mut budget = 0.0f64;

        loop {
            // Wake everything due by `now`.
            self.wake_due(now);

            if self.ready.is_empty() {
                match self.next_wake() {
                    Some(at) => {
                        // Idle cycles until the next wake-up.
                        now = at;
                        budget = peak; // a fresh cycle's budget awaits
                        continue;
                    }
                    None => break, // drained
                }
            }

            // Issue phase for this cycle.
            budget += peak;
            // Cap the carried budget: hardware cannot bank issue slots.
            if budget > peak.max(1.0) {
                budget = peak.max(1.0);
            }
            while budget >= 1.0 {
                let Some(w) = self.ready.pop_front() else { break };
                budget -= 1.0;
                self.issue(w, now);
            }
            now += 1.0;
        }

        SimResult { cycles: now, kernels: self.metrics.clone() }
    }

    /// Issue one instruction of warp `w` at cycle `now`.
    // lint: no-alloc
    fn issue(&mut self, w: usize, now: f64) {
        let (kernel, slot) = (self.warps[w].kernel, self.warps[w].block_slot);
        let spec = &self.workloads[kernel].spec;
        let mix = spec.mix;
        self.metrics[kernel].insts += 1;
        self.warps[w].remaining -= 1;

        let finished = self.warps[w].remaining == 0;
        if finished {
            self.free_warps.push(w);
            let s = &mut self.slots[slot];
            s.warps_left -= 1;
            if s.warps_left == 0 && s.live {
                s.live = false;
                self.free_slots.push(slot);
                let spec = self.workloads[kernel].spec.clone();
                self.resources.release(&self.gpu, &spec);
                self.resident_blocks[kernel] -= 1;
                self.metrics[kernel].blocks_completed += 1;
                self.refill();
            }
            return;
        }

        if self.rng.chance(mix.mem_ratio) {
            // Global memory instruction.
            self.metrics[kernel].mem_insts += 1;
            let sectors = if mix.uncoalesced_frac > 0.0 && self.rng.chance(mix.uncoalesced_frac) {
                mix.uncoalesced_fanout
            } else {
                4 // one coalesced 128B transaction
            };
            self.metrics[kernel].sectors += sectors as u64;
            let wake = self.memory.access(now, sectors);
            debug_assert!(self.mem_sleep.back().map_or(true, |&(at, _)| at <= wake));
            self.mem_sleep.push_back((wake, w));
        } else {
            // Arithmetic: dependent-issue gap of arith_latency/ilp
            // cycles on average (1.0 = back-to-back). Dual-issue
            // schedulers (Kepler: 2 instr/scheduler/cycle) pair
            // independent instructions statically, effectively halving
            // the per-warp dependency gap.
            let dual = self.gpu.issue_per_scheduler.max(1.0);
            let lat = spec.arith_latency as f64 * self.gpu.arith_latency_scale;
            let gap = (lat / (spec.ilp * dual)).max(1.0);
            if gap <= 1.0 {
                self.ready.push_back(w);
            } else {
                debug_assert!(self.arith_sleep[kernel]
                    .back()
                    .map_or(true, |&(at, _)| at <= now + gap));
                self.arith_sleep[kernel].push_back((now + gap, w));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::InstructionMix;

    fn spec(mem: f64, ilp: f64) -> KernelSpec {
        KernelSpec {
            name: "t",
            grid_blocks: 64,
            threads_per_block: 128,
            regs_per_thread: 16,
            smem_per_block: 0,
            inst_per_warp: 256,
            mix: InstructionMix::coalesced(mem),
            arith_latency: 20,
            ilp,
        }
    }

    #[test]
    fn drains_all_blocks() {
        let gpu = GpuConfig::c2050();
        let mut e = SmEngine::new(&gpu, 1);
        e.add_workload(Workload::new(spec(0.1, 2.0), 10));
        let r = e.run();
        assert_eq!(r.kernels[0].blocks_completed, 10);
        assert_eq!(r.kernels[0].insts, 10 * 4 * 256);
        assert!(r.cycles > 0.0);
    }

    #[test]
    fn residency_respects_block_cap() {
        // 8-block cap on Fermi: a 9th block must wait. Indirectly
        // observable: tiny 32-thread blocks, pure compute, the run must
        // still drain and complete exactly `blocks`.
        let gpu = GpuConfig::c2050();
        let mut k = spec(0.0, 4.0);
        k.threads_per_block = 32;
        let mut e = SmEngine::new(&gpu, 2);
        e.add_workload(Workload::new(k, 20));
        let r = e.run();
        assert_eq!(r.kernels[0].blocks_completed, 20);
    }

    #[test]
    fn two_workloads_share_residency() {
        let gpu = GpuConfig::c2050();
        let mut e = SmEngine::new(&gpu, 3);
        e.add_workload(Workload::new(spec(0.0, 2.0), 6));
        e.add_workload(Workload::new(spec(0.4, 1.0), 6));
        let r = e.run();
        assert_eq!(r.kernels[0].blocks_completed, 6);
        assert_eq!(r.kernels[1].blocks_completed, 6);
    }

    #[test]
    fn low_ilp_lowers_ipc() {
        let gpu = GpuConfig::c2050();
        let mut hi = SmEngine::new(&gpu, 4);
        hi.add_workload(Workload::new(spec(0.0, 4.0), 24));
        let r_hi = hi.run();
        let mut lo = SmEngine::new(&gpu, 4);
        // Same work, heavy dependency stalls.
        let mut k = spec(0.0, 0.3);
        k.arith_latency = 40;
        lo.add_workload(Workload::new(k, 24));
        let r_lo = lo.run();
        assert!(r_lo.cycles > r_hi.cycles * 1.5, "lo={} hi={}", r_lo.cycles, r_hi.cycles);
    }

    #[test]
    fn reset_engine_matches_fresh_engine_bitwise() {
        // `reset` must leave no trace of the previous run: a dirtied,
        // reset engine replays a simulation bit-for-bit identically to
        // a freshly constructed one (the SimScratch correctness
        // contract).
        let gpu = GpuConfig::c2050();
        let mut fresh = SmEngine::new(&gpu, 7);
        fresh.add_workload(Workload::new(spec(0.3, 1.5), 12));
        let a = fresh.run();
        let mut reused = SmEngine::new(&GpuConfig::gtx680(), 99);
        reused.add_workload(Workload::new(spec(0.1, 2.0), 5));
        reused.add_workload(Workload::new(spec(0.4, 1.0), 5));
        let _ = reused.run();
        reused.reset(&gpu, 7);
        reused.add_workload(Workload::new(spec(0.3, 1.5), 12));
        let b = reused.run();
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
        assert_eq!(a.kernels, b.kernels);
    }

    #[test]
    fn kepler_issues_faster_than_fermi() {
        let k = spec(0.0, 4.0);
        let mut f = SmEngine::new(&GpuConfig::c2050(), 5);
        f.add_workload(Workload::new(k.clone(), 16));
        let rf = f.run();
        let mut g = SmEngine::new(&GpuConfig::gtx680(), 5);
        g.add_workload(Workload::new(k, 16));
        let rg = g.run();
        // Kepler's peak IPC is 8x Fermi's; pure-ALU work should finish
        // several times quicker.
        assert!(rg.cycles < rf.cycles / 2.0, "kepler={} fermi={}", rg.cycles, rf.cycles);
    }
}
