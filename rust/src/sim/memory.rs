//! Per-SM memory subsystem: pipeline latency + bandwidth queue.
//!
//! Every global-memory instruction turns into a burst of 32-byte
//! sectors (4 for a coalesced 128B transaction, `fanout` for a fully
//! uncoalesced one). Sectors drain through a deterministic-service
//! single queue at the SM's DRAM bandwidth share; the requesting warp
//! wakes when its last sector has been serviced plus the fixed pipeline
//! latency. Under load the queueing delay grows linearly with the
//! number of outstanding sectors — the behaviour the paper captures
//! with its linear model `L = L0 + f(outstanding)/B` (§4.4).

use crate::config::GpuConfig;

/// The memory pipeline of one SM.
#[derive(Debug, Clone)]
pub struct MemoryPipe {
    /// Fixed (uncontended) latency in cycles.
    base_latency: f64,
    /// Service rate in sectors per cycle.
    sectors_per_cycle: f64,
    /// Cycle at which the bandwidth server becomes free.
    next_free: f64,
    /// Total sectors serviced (MUR numerator).
    pub sectors_total: u64,
}

impl MemoryPipe {
    /// A memory pipe with `gpu`'s latency/bandwidth parameters.
    pub fn new(gpu: &GpuConfig) -> Self {
        Self {
            base_latency: gpu.mem_latency_cycles,
            sectors_per_cycle: gpu.dram_sectors_per_cycle_per_sm(),
            next_free: 0.0,
            sectors_total: 0,
        }
    }

    /// Issue a memory access of `sectors` sectors at cycle `now`.
    /// Returns the cycle at which the data is available (the issuing
    /// warp's wake-up time).
    pub fn access(&mut self, now: f64, sectors: u32) -> f64 {
        debug_assert!(sectors >= 1);
        let start = self.next_free.max(now);
        let service = sectors as f64 / self.sectors_per_cycle;
        self.next_free = start + service;
        self.sectors_total += sectors as u64;
        self.next_free + self.base_latency
    }

    /// Current queueing backlog in cycles (0 when idle) — exposed for
    /// metrics and tests.
    pub fn backlog(&self, now: f64) -> f64 {
        (self.next_free - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipe() -> MemoryPipe {
        let gpu = GpuConfig::c2050();
        MemoryPipe::new(&gpu)
    }

    #[test]
    fn uncontended_access_costs_base_latency() {
        let mut m = pipe();
        let done = m.access(100.0, 4);
        let service = 4.0 / GpuConfig::c2050().dram_sectors_per_cycle_per_sm();
        assert!((done - (100.0 + service + 440.0)).abs() < 1e-9);
    }

    #[test]
    fn contention_grows_linearly() {
        let mut m = pipe();
        let mut last = 0.0;
        let mut gaps = Vec::new();
        for _ in 0..10 {
            let done = m.access(0.0, 4);
            gaps.push(done - last);
            last = done;
        }
        // After the first access every completion is spaced by exactly
        // the service time — linear latency growth with backlog.
        let service = 4.0 / GpuConfig::c2050().dram_sectors_per_cycle_per_sm();
        for g in &gaps[1..] {
            assert!((g - service).abs() < 1e-9, "gap={g} service={service}");
        }
    }

    #[test]
    fn uncoalesced_burst_costs_more() {
        let mut a = pipe();
        let mut b = pipe();
        let t_coal = a.access(0.0, 4);
        let t_unco = b.access(0.0, 16);
        assert!(t_unco > t_coal);
    }

    #[test]
    fn backlog_drains() {
        let mut m = pipe();
        m.access(0.0, 400);
        assert!(m.backlog(0.0) > 0.0);
        let free_at = m.backlog(0.0);
        assert_eq!(m.backlog(free_at + 1.0), 0.0);
    }

    #[test]
    fn sector_accounting() {
        let mut m = pipe();
        m.access(0.0, 4);
        m.access(0.0, 16);
        assert_eq!(m.sectors_total, 20);
    }
}
