//! Simulation counters and derived performance metrics.

use crate::config::GpuConfig;

/// Counters for one kernel within a simulation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelMetrics {
    /// Dynamic instructions issued (warp-instructions).
    pub insts: u64,
    /// Of those, global-memory instructions (profiler input for R_m).
    pub mem_insts: u64,
    /// 32-byte DRAM sectors generated.
    pub sectors: u64,
    /// Thread blocks run to completion.
    pub blocks_completed: u32,
    /// Cycles during which this kernel had at least one resident warp
    /// (used for per-kernel solo-equivalent IPC in tail phases).
    pub resident_cycles: f64,
}

impl KernelMetrics {
    /// Accumulate another SM's counters into this one.
    pub fn absorb(&mut self, other: &KernelMetrics) {
        self.insts += other.insts;
        self.mem_insts += other.mem_insts;
        self.sectors += other.sectors;
        self.blocks_completed += other.blocks_completed;
        self.resident_cycles += other.resident_cycles;
    }
}

/// Result of one SM simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    /// Total cycles simulated until all workloads drained.
    pub cycles: f64,
    /// Per-workload counters, in `add_workload` order.
    pub kernels: Vec<KernelMetrics>,
}

impl SimResult {
    /// Aggregate instructions across kernels.
    pub fn total_insts(&self) -> u64 {
        self.kernels.iter().map(|k| k.insts).sum()
    }

    /// Aggregate sectors across kernels.
    pub fn total_sectors(&self) -> u64 {
        self.kernels.iter().map(|k| k.sectors).sum()
    }

    /// SM instructions per cycle.
    pub fn ipc(&self, _gpu: &GpuConfig) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.total_insts() as f64 / self.cycles
        }
    }

    /// Pipeline utilization ratio: IPC normalized by the SM's peak issue
    /// rate (paper §4.3).
    pub fn pur(&self, gpu: &GpuConfig) -> f64 {
        self.ipc(gpu) / gpu.peak_ipc()
    }

    /// Memory-bandwidth utilization ratio: sector rate normalized by the
    /// SM's peak LSU sector rate (paper §4.3 Peak_MPC).
    pub fn mur(&self, gpu: &GpuConfig) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.total_sectors() as f64 / self.cycles / gpu.lsu_sectors_per_cycle
        }
    }

    /// Wall-clock seconds on this GPU.
    pub fn seconds(&self, gpu: &GpuConfig) -> f64 {
        gpu.cycles_to_secs(self.cycles)
    }

    /// Merge a sequentially-following run into this one (sliced
    /// execution accounting).
    pub fn absorb(&mut self, other: &SimResult) {
        self.cycles += other.cycles;
        while self.kernels.len() < other.kernels.len() {
            self.kernels.push(KernelMetrics::default());
        }
        for (mine, theirs) in self.kernels.iter_mut().zip(&other.kernels) {
            mine.absorb(theirs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics() {
        let gpu = GpuConfig::c2050();
        let r = SimResult {
            cycles: 1000.0,
            kernels: vec![KernelMetrics { insts: 500, mem_insts: 100, sectors: 800, blocks_completed: 4, resident_cycles: 1000.0 }],
        };
        assert!((r.ipc(&gpu) - 0.5).abs() < 1e-12);
        assert!((r.pur(&gpu) - 0.5).abs() < 1e-12);
        assert!((r.mur(&gpu) - 0.2).abs() < 1e-12);
        assert!((r.seconds(&gpu) - 1000.0 / 1.147e9).abs() < 1e-18);
    }

    #[test]
    fn absorb_accumulates() {
        let mut a = SimResult {
            cycles: 10.0,
            kernels: vec![KernelMetrics { insts: 1, mem_insts: 1, sectors: 2, blocks_completed: 1, resident_cycles: 10.0 }],
        };
        let b = SimResult {
            cycles: 5.0,
            kernels: vec![KernelMetrics { insts: 3, mem_insts: 2, sectors: 4, blocks_completed: 2, resident_cycles: 5.0 }],
        };
        a.absorb(&b);
        assert_eq!(a.cycles, 15.0);
        assert_eq!(a.kernels[0].insts, 4);
        assert_eq!(a.kernels[0].sectors, 6);
        assert_eq!(a.kernels[0].blocks_completed, 3);
    }

    #[test]
    fn empty_result_is_zero() {
        let gpu = GpuConfig::c2050();
        let r = SimResult::default();
        assert_eq!(r.ipc(&gpu), 0.0);
        assert_eq!(r.mur(&gpu), 0.0);
    }
}
