//! Cycle-level stochastic GPU simulator — the measurement substrate.
//!
//! The paper measures IPC/PUR/MUR/execution-time on real Fermi and
//! Kepler silicon; this environment has neither, so every "measured"
//! number in the reproduction comes from this simulator (see DESIGN.md
//! §2 for the substitution argument). The simulator implements the
//! mechanisms the paper's analytic model approximates:
//!
//! - per-SM warp population with round-robin warp schedulers and a
//!   per-cycle issue budget (0.5 instr/scheduler on Fermi, 2.0 with dual
//!   issue on Kepler);
//! - a memory pipeline per SM: fixed pipeline latency plus a
//!   deterministic-service bandwidth queue in 32-byte sectors, which
//!   yields the linear latency-vs-outstanding-requests behaviour the
//!   paper models as `L = L0 + f(outstanding)/B`;
//! - coalesced (4-sector) vs fully uncoalesced (fanout-sector) memory
//!   instructions;
//! - a block dispatcher with resource-limited co-residency of blocks
//!   from two kernels (registers, shared memory, threads, block slots);
//! - per-slice kernel launch overhead (the source of Fig. 6's curves);
//! - compute-pipeline dependency stalls (arith latency / ILP) which the
//!   paper's model ignores — deliberately kept so the model-vs-measured
//!   gaps in Figs. 7-12 are honest.
//!
//! One SM is simulated and treated as representative (the paper's own
//! SPMD argument in §4.4); grid blocks are distributed round-robin, so
//! the representative SM receives `ceil(blocks / num_sms)`.

pub mod engine;
pub mod memory;
pub mod metrics;

pub use engine::{SmEngine, Workload};
pub use metrics::{KernelMetrics, SimResult};

use crate::config::GpuConfig;
use crate::kernel::KernelSpec;
use std::cell::RefCell;

/// Default RNG seed for measurement runs (fixed for reproducibility).
pub const DEFAULT_SEED: u64 = 0xC2050_680;

/// Reusable simulation buffers: one [`SmEngine`] whose internal vectors
/// and rings keep their capacity across runs.
///
/// The cold path (slice-size probing, pair-round aggregation, cache
/// prewarming) runs thousands of short simulations; constructing a
/// fresh engine for each reallocates every buffer. The `*_with` entry
/// points below thread a `SimScratch` through instead, and the plain
/// entry points delegate to a thread-local one — results are bitwise
/// identical either way ([`SmEngine::reset`]'s contract, pinned by
/// tests here and in `tests/coldpath_invariants.rs`).
#[derive(Default)]
pub struct SimScratch {
    engine: Option<SmEngine>,
}

impl SimScratch {
    /// An empty scratch; buffers are grown on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// A reset engine for `(gpu, seed)`, reusing the previous run's
    /// buffers when they exist.
    fn engine(&mut self, gpu: &GpuConfig, seed: u64) -> &mut SmEngine {
        match &mut self.engine {
            Some(e) => e.reset(gpu, seed),
            None => self.engine = Some(SmEngine::new(gpu, seed)),
        }
        self.engine.as_mut().expect("engine ensured above")
    }
}

thread_local! {
    /// Per-thread scratch backing the scratch-less entry points.
    static SIM_SCRATCH: RefCell<SimScratch> = RefCell::new(SimScratch::new());
}

/// Run `f` with this thread's simulation scratch. Not re-entrant: `f`
/// must not call the scratch-less `simulate_*` entry points (the
/// `*_with` variants it can call take the scratch explicitly).
fn with_sim_scratch<R>(f: impl FnOnce(&mut SimScratch) -> R) -> R {
    SIM_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Blocks the representative SM receives out of a `total` distributed
/// round-robin over the GPU.
pub fn blocks_on_sm(gpu: &GpuConfig, total: u32) -> u32 {
    total.div_ceil(gpu.num_sms)
}

/// Simulate a full solo (unsliced) kernel execution.
///
/// Returns per-SM metrics; execution time in cycles includes one kernel
/// launch overhead.
pub fn simulate_solo(gpu: &GpuConfig, spec: &KernelSpec, seed: u64) -> SimResult {
    with_sim_scratch(|s| simulate_solo_with(s, gpu, spec, seed))
}

/// [`simulate_solo`] against caller-owned scratch buffers.
pub fn simulate_solo_with(
    scratch: &mut SimScratch,
    gpu: &GpuConfig,
    spec: &KernelSpec,
    seed: u64,
) -> SimResult {
    let blocks = blocks_on_sm(gpu, spec.grid_blocks);
    let eng = scratch.engine(gpu, seed);
    eng.add_workload(Workload::new(spec.clone(), blocks));
    let mut res = eng.run();
    res.cycles += gpu.launch_overhead_cycles;
    res
}

/// Simulate a solo kernel executed as a sequence of slices of
/// `slice_size` blocks (grid-wide) — the Fig. 6 setup.
///
/// Architecture matters here (and is exactly Fig. 6's finding):
/// - **Fermi** has a single in-order launch queue: every slice pays its
///   launch overhead serially AND the SM drains between slices (the
///   occupancy ramp bubbles). Each slice is simulated separately.
/// - **Kepler** (Hyper-Q era) pipelines back-to-back launches: the next
///   slice's blocks start filling as the previous drains, so the drain
///   bubbles vanish and only the (cheap) per-launch costs remain.
pub fn simulate_solo_sliced(gpu: &GpuConfig, spec: &KernelSpec, slice_size: u32, seed: u64) -> SimResult {
    with_sim_scratch(|s| simulate_solo_sliced_with(s, gpu, spec, slice_size, seed))
}

/// [`simulate_solo_sliced`] against caller-owned scratch buffers: the
/// Fermi path resets one engine per slice instead of constructing one.
pub fn simulate_solo_sliced_with(
    scratch: &mut SimScratch,
    gpu: &GpuConfig,
    spec: &KernelSpec,
    slice_size: u32,
    seed: u64,
) -> SimResult {
    assert!(slice_size >= 1);
    let n_slices = spec.grid_blocks.div_ceil(slice_size) as f64;
    match gpu.arch {
        crate::config::Arch::Fermi => {
            let mut remaining = spec.grid_blocks;
            let mut agg = SimResult::default();
            let mut slice_idx = 0u64;
            while remaining > 0 {
                let this = remaining.min(slice_size);
                remaining -= this;
                let blocks = blocks_on_sm(gpu, this);
                let eng = scratch.engine(gpu, seed ^ (0x51ce << 16) ^ slice_idx);
                eng.add_workload(Workload::new(spec.clone(), blocks));
                let r = eng.run();
                agg.absorb(&r);
                agg.cycles += gpu.launch_overhead_cycles;
                slice_idx += 1;
            }
            agg
        }
        crate::config::Arch::Kepler => {
            // Pipelined launches: blocks stream continuously; per-slice
            // launch costs accumulate but the SM never drains.
            let blocks = blocks_on_sm(gpu, spec.grid_blocks);
            let eng = scratch.engine(gpu, seed ^ (0x51ce << 16));
            eng.add_workload(Workload::new(spec.clone(), blocks));
            let mut r = eng.run();
            r.cycles += gpu.launch_overhead_cycles * n_slices;
            r
        }
    }
}

/// Result of co-running one slice pair to completion on the SM.
#[derive(Debug, Clone)]
pub struct PairResult {
    /// Total cycles until both slices drained (includes one launch
    /// overhead for the round — concurrent launches overlap in separate
    /// streams, so the pair pays max(two launches) ~= one).
    pub cycles: f64,
    /// Per-kernel metrics, indexed like the input pair.
    pub per_kernel: [KernelMetrics; 2],
}

impl PairResult {
    /// Concurrent IPC of kernel `i` (instructions / total cycles).
    pub fn cipc(&self, i: usize) -> f64 {
        self.per_kernel[i].insts as f64 / self.cycles
    }

    /// Aggregate IPC over both kernels.
    pub fn total_ipc(&self) -> f64 {
        (self.per_kernel[0].insts + self.per_kernel[1].insts) as f64 / self.cycles
    }
}

/// Simulate one co-schedule round: a slice of `s1` grid blocks from
/// `k1` (at most `q1` blocks co-resident per SM) concurrently with a
/// slice of `s2` blocks from `k2` (quota `q2`).
///
/// The quotas are the co-schedule's residency split (b1, b2): they pin
/// each slice's occupancy share, which is the whole point of slice-size
/// tuning in the paper. Feasibility of (q1, q2) is the caller's
/// responsibility ([`crate::coordinator::coresident_feasible`]).
pub fn simulate_pair(
    gpu: &GpuConfig,
    k1: &KernelSpec,
    s1: u32,
    q1: u32,
    k2: &KernelSpec,
    s2: u32,
    q2: u32,
    seed: u64,
) -> PairResult {
    with_sim_scratch(|sc| simulate_pair_with(sc, gpu, k1, s1, q1, k2, s2, q2, seed))
}

/// [`simulate_pair`] against caller-owned scratch buffers.
#[allow(clippy::too_many_arguments)]
pub fn simulate_pair_with(
    scratch: &mut SimScratch,
    gpu: &GpuConfig,
    k1: &KernelSpec,
    s1: u32,
    q1: u32,
    k2: &KernelSpec,
    s2: u32,
    q2: u32,
    seed: u64,
) -> PairResult {
    assert!(s1 >= 1 && s2 >= 1);
    let eng = scratch.engine(gpu, seed);
    eng.add_workload(Workload::with_quota(k1.clone(), blocks_on_sm(gpu, s1), q1));
    eng.add_workload(Workload::with_quota(k2.clone(), blocks_on_sm(gpu, s2), q2));
    let res = eng.run();
    PairResult {
        cycles: res.cycles + gpu.launch_overhead_cycles,
        per_kernel: [res.kernels[0].clone(), res.kernels[1].clone()],
    }
}

/// Steady-state co-run estimate: repeat the slice pair `rounds` times
/// with different seeds and aggregate (cheap variance reduction for the
/// scheduler's OPT oracle and the figures).
#[allow(clippy::too_many_arguments)]
pub fn simulate_pair_rounds(
    gpu: &GpuConfig,
    k1: &KernelSpec,
    s1: u32,
    q1: u32,
    k2: &KernelSpec,
    s2: u32,
    q2: u32,
    rounds: u32,
    seed: u64,
) -> PairResult {
    with_sim_scratch(|sc| {
        simulate_pair_rounds_with(sc, gpu, k1, s1, q1, k2, s2, q2, rounds, seed)
    })
}

/// [`simulate_pair_rounds`] against caller-owned scratch buffers: all
/// `rounds` runs share one engine.
#[allow(clippy::too_many_arguments)]
pub fn simulate_pair_rounds_with(
    scratch: &mut SimScratch,
    gpu: &GpuConfig,
    k1: &KernelSpec,
    s1: u32,
    q1: u32,
    k2: &KernelSpec,
    s2: u32,
    q2: u32,
    rounds: u32,
    seed: u64,
) -> PairResult {
    assert!(rounds >= 1);
    let mut cycles = 0.0;
    let mut agg = [KernelMetrics::default(), KernelMetrics::default()];
    for r in 0..rounds {
        let pr = simulate_pair_with(
            scratch,
            gpu,
            k1,
            s1,
            q1,
            k2,
            s2,
            q2,
            seed.wrapping_add(r as u64 * 0x9E37),
        );
        cycles += pr.cycles;
        agg[0].absorb(&pr.per_kernel[0]);
        agg[1].absorb(&pr.per_kernel[1]);
    }
    PairResult { cycles, per_kernel: agg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{BenchmarkApp, InstructionMix};

    fn mini(mem_ratio: f64) -> KernelSpec {
        KernelSpec {
            name: "mini",
            // Large enough that the one-off launch overhead (8600 cycles
            // on C2050) is amortized below 2%, like real Table 3 grids.
            grid_blocks: 1120,
            threads_per_block: 256,
            regs_per_thread: 20,
            smem_per_block: 0,
            inst_per_warp: 512,
            mix: InstructionMix::coalesced(mem_ratio),
            arith_latency: 20,
            ilp: 2.0,
        }
    }

    #[test]
    fn solo_compute_bound_saturates_pipeline() {
        let gpu = GpuConfig::c2050();
        let r = simulate_solo(&gpu, &mini(0.0), 1);
        // 48 warps of pure ALU with ilp 2 must keep IPC near peak (1.0).
        assert!(r.ipc(&gpu) > 0.9, "ipc={}", r.ipc(&gpu));
        assert!(r.pur(&gpu) > 0.9);
        assert!(r.mur(&gpu) < 0.01);
    }

    #[test]
    fn solo_memory_bound_is_slow() {
        let gpu = GpuConfig::c2050();
        let r = simulate_solo(&gpu, &mini(0.5), 1);
        assert!(r.ipc(&gpu) < 0.3, "ipc={}", r.ipc(&gpu));
        assert!(r.mur(&gpu) > 0.02, "mur={}", r.mur(&gpu));
    }

    #[test]
    fn instruction_accounting_exact() {
        let gpu = GpuConfig::c2050();
        let spec = mini(0.1);
        let r = simulate_solo(&gpu, &spec, 7);
        let blocks = blocks_on_sm(&gpu, spec.grid_blocks);
        let expect = blocks as u64 * spec.inst_per_block(&gpu);
        assert_eq!(r.kernels[0].insts, expect);
        assert_eq!(r.kernels[0].blocks_completed, blocks);
    }

    #[test]
    fn sliced_never_faster_than_unsliced() {
        let gpu = GpuConfig::c2050();
        let spec = BenchmarkApp::MM.spec().with_grid(256);
        let whole = simulate_solo(&gpu, &spec, 3);
        let sliced = simulate_solo_sliced(&gpu, &spec, 14, 3);
        assert!(
            sliced.cycles > whole.cycles,
            "sliced={} whole={}",
            sliced.cycles,
            whole.cycles
        );
        // Same total work regardless of slicing.
        assert_eq!(sliced.kernels[0].insts, whole.kernels[0].insts);
    }

    #[test]
    fn pair_conserves_work() {
        let gpu = GpuConfig::c2050();
        let (a, b) = (mini(0.0), mini(0.4));
        let pr = simulate_pair(&gpu, &a, 28, 3, &b, 28, 3, 11);
        let blocks = blocks_on_sm(&gpu, 28);
        assert_eq!(pr.per_kernel[0].insts, blocks as u64 * a.inst_per_block(&gpu));
        assert_eq!(pr.per_kernel[1].insts, blocks as u64 * b.inst_per_block(&gpu));
        assert!(pr.total_ipc() > 0.0);
    }

    #[test]
    fn complementary_pair_beats_serial() {
        // A compute kernel co-run with a memory kernel should finish in
        // less time than running the two slices back to back — the
        // paper's core premise.
        let gpu = GpuConfig::c2050();
        let compute = mini(0.0);
        let memory = mini(0.5);
        let solo_c = simulate_solo(&gpu, &compute.with_grid(280), 5);
        let solo_m = simulate_solo(&gpu, &memory.with_grid(280), 6);
        let pair = simulate_pair(&gpu, &compute, 280, 3, &memory, 280, 3, 7);
        let serial = solo_c.cycles + solo_m.cycles;
        assert!(
            pair.cycles < serial * 0.95,
            "pair={} serial={}",
            pair.cycles,
            serial
        );
    }

    #[test]
    fn scratch_variants_match_fresh_engines_bitwise() {
        // Each `*_with` entry point run against a heavily dirtied
        // scratch must reproduce the scratch-less result bit for bit —
        // solo, sliced (both arches exercise through the two gpus) and
        // multi-round pair.
        let fermi = GpuConfig::c2050();
        let kepler = GpuConfig::gtx680();
        let (a, b) = (mini(0.1), mini(0.4));
        let mut dirty = SimScratch::new();
        let _ = simulate_pair_rounds_with(&mut dirty, &kepler, &a, 56, 2, &b, 56, 2, 3, 77);
        for gpu in [&fermi, &kepler] {
            let solo = simulate_solo(gpu, &a, 42);
            let solo_s = simulate_solo_with(&mut dirty, gpu, &a, 42);
            assert_eq!(solo.cycles.to_bits(), solo_s.cycles.to_bits());
            assert_eq!(solo.kernels, solo_s.kernels);
            let sliced = simulate_solo_sliced(gpu, &a, gpu.num_sms * 2, 42);
            let sliced_s = simulate_solo_sliced_with(&mut dirty, gpu, &a, gpu.num_sms * 2, 42);
            assert_eq!(sliced.cycles.to_bits(), sliced_s.cycles.to_bits());
            assert_eq!(sliced.kernels, sliced_s.kernels);
            let pair = simulate_pair_rounds(gpu, &a, 56, 3, &b, 56, 3, 4, 9);
            let pair_s = simulate_pair_rounds_with(&mut dirty, gpu, &a, 56, 3, &b, 56, 3, 4, 9);
            assert_eq!(pair.cycles.to_bits(), pair_s.cycles.to_bits());
            assert_eq!(pair.per_kernel, pair_s.per_kernel);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let gpu = GpuConfig::gtx680();
        let spec = mini(0.2);
        let a = simulate_solo(&gpu, &spec, 42);
        let b = simulate_solo(&gpu, &spec, 42);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.kernels[0].sectors, b.kernels[0].sectors);
    }
}
