//! Kernel slicer: minimum slice size under an overhead budget
//! (paper §4.1).
//!
//! Slicing a kernel into n slices costs n kernel launches plus partial
//! occupancy at each slice boundary. Kernelet "experimentally determines
//! the suitable slice size to be the minimum slice so that the overhead
//! is not greater than p% of the kernel execution time" (p = 2 by
//! default). Candidate sizes are multiples of the SM count (the Fig. 6
//! sweep), and the result is cached per kernel ("if the kernel has been
//! submitted before, we simply use the smallest slice size in the
//! previous execution").
//!
//! The code-level transform that makes a slice launchable — index
//! rectification on PTX — lives in [`crate::ptx::rectify`]; this module
//! only decides *sizes*.

use crate::config::GpuConfig;
use crate::kernel::KernelSpec;
use crate::sharded::ShardedMap;
use crate::sim;

/// Default overhead budget: 2% (paper §4.1).
pub const DEFAULT_OVERHEAD_PCT: f64 = 2.0;

/// Relative slicing overhead of executing `spec` in slices of
/// `slice_size` blocks: `T_s / T_ns − 1` (the Fig. 6 y-axis).
pub fn slicing_overhead(gpu: &GpuConfig, spec: &KernelSpec, slice_size: u32, seed: u64) -> f64 {
    let whole = sim::simulate_solo(gpu, spec, seed);
    let sliced = sim::simulate_solo_sliced(gpu, spec, slice_size, seed);
    sliced.cycles / whole.cycles - 1.0
}

/// The Fig. 6 sweep: candidate slice sizes from |SM| up to the full
/// residency footprint, in |SM| multiples.
pub fn candidate_sizes(gpu: &GpuConfig, spec: &KernelSpec) -> Vec<u32> {
    let max_mult = spec.blocks_per_sm(gpu).max(1) * 3; // up to 3 generations
    (1..=max_mult).map(|m| m * gpu.num_sms).collect()
}

/// Find the minimum slice size whose overhead is within `budget_pct`.
///
/// Falls back to the whole grid if even the largest candidate exceeds
/// the budget (degenerates to non-sliced execution, as the paper notes
/// for the extreme).
///
/// Since the cold-path perf pass this runs a monotone binary search —
/// overhead decreases with slice size (fewer launches, fewer partial
/// tails), so the budget predicate over the ordered candidate list is
/// `false… true…` and a lower-bound search returns the same answer as
/// the seed's linear scan (kept as [`min_slice_size_linear`] and pinned
/// bit-identical by an exhaustive differential test) while simulating
/// O(log n) candidates.
pub fn min_slice_size(gpu: &GpuConfig, spec: &KernelSpec, budget_pct: f64, seed: u64) -> u32 {
    min_slice_size_counted(gpu, spec, budget_pct, seed).0
}

/// [`min_slice_size`] plus the number of candidate slice sizes actually
/// simulated — the deterministic work counter `BENCH_model.json`
/// compares against the linear reference.
pub fn min_slice_size_counted(
    gpu: &GpuConfig,
    spec: &KernelSpec,
    budget_pct: f64,
    seed: u64,
) -> (u32, usize) {
    let candidates: Vec<u32> = candidate_sizes(gpu, spec)
        .into_iter()
        .take_while(|&size| size < spec.grid_blocks)
        .collect();
    if candidates.is_empty() {
        return (spec.grid_blocks, 0);
    }
    // The whole-grid run is candidate-independent: simulate it once
    // instead of once per probe (deterministic, so the per-candidate
    // overhead value is float-identical to `slicing_overhead`'s).
    let whole = sim::simulate_solo(gpu, spec, seed);
    let mut simulated = 0usize;
    let (mut lo, mut hi) = (0usize, candidates.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        simulated += 1;
        let sliced = sim::simulate_solo_sliced(gpu, spec, candidates[mid], seed);
        let within = (sliced.cycles / whole.cycles - 1.0) * 100.0 <= budget_pct;
        if within {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    if lo == candidates.len() {
        (spec.grid_blocks, simulated)
    } else {
        (candidates[lo], simulated)
    }
}

/// The seed's linear scan, kept verbatim as the frozen reference the
/// binary search is differentially pinned against
/// (`tests/coldpath_invariants.rs`). Prefer [`min_slice_size`].
pub fn min_slice_size_linear(gpu: &GpuConfig, spec: &KernelSpec, budget_pct: f64, seed: u64) -> u32 {
    min_slice_size_linear_counted(gpu, spec, budget_pct, seed).0
}

/// [`min_slice_size_linear`] plus the number of candidates simulated.
pub fn min_slice_size_linear_counted(
    gpu: &GpuConfig,
    spec: &KernelSpec,
    budget_pct: f64,
    seed: u64,
) -> (u32, usize) {
    let mut simulated = 0usize;
    for size in candidate_sizes(gpu, spec) {
        if size >= spec.grid_blocks {
            break;
        }
        simulated += 1;
        if slicing_overhead(gpu, spec, size, seed) * 100.0 <= budget_pct {
            return (size, simulated);
        }
    }
    (spec.grid_blocks, simulated)
}

/// Cache of minimum slice sizes keyed by (gpu, kernel name, grid,
/// budget).
///
/// The budget is part of the key as its exact f64 bit pattern: the seed
/// omitted it, so whichever budget probed a kernel first silently won
/// for every later query with a different budget. The grid is in the
/// key too — [`min_slice_size`] breaks and falls back on
/// `spec.grid_blocks`, and trace replay can submit same-name kernels
/// with overridden grids. Sharded storage (see [`crate::sharded`])
/// keeps concurrent engines off a single lock.
#[derive(Default)]
pub struct SliceSizeCache {
    map: ShardedMap<(String, String, u32, u64), u32>,
}

impl SliceSizeCache {
    /// An empty slice-size cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Minimum slice size (cached) for `spec` on `gpu` under
    /// `budget_pct` percent launch-overhead budget.
    pub fn get(&self, gpu: &GpuConfig, spec: &KernelSpec, budget_pct: f64) -> u32 {
        let key = (
            gpu.name.to_string(),
            spec.name.to_string(),
            spec.grid_blocks,
            budget_pct.to_bits(),
        );
        if let Some(s) = self.map.get(&key) {
            return s;
        }
        let s = min_slice_size(gpu, spec, budget_pct, sim::DEFAULT_SEED ^ 0x511CE);
        self.map.insert(key, s);
        s
    }

    /// Copy every cached slice size of `other` into this cache. The
    /// key carries the GPU name, kernel, grid and budget, so entries
    /// from any donor are safe to hold — lookups for other
    /// configurations can never alias them. Returns the entry count.
    pub fn absorb(&self, other: &SliceSizeCache) -> usize {
        self.map.absorb(&other.map)
    }

    /// Cached slice sizes so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// [`SliceSizeCache::get`] behind the analyzer's safety gate: an
    /// unsliceable kernel's "slice" is its whole grid, bypassing both
    /// the sweep and the cache (no point memoizing a constant, and the
    /// sweep's simulated slicing would be meaningless for a kernel
    /// that must never be sliced).
    pub fn get_gated(
        &self,
        gpu: &GpuConfig,
        spec: &KernelSpec,
        budget_pct: f64,
        sliceable: bool,
    ) -> u32 {
        if !sliceable {
            return spec.grid_blocks;
        }
        self.get(gpu, spec, budget_pct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::BenchmarkApp;

    #[test]
    fn overhead_decreases_with_slice_size() {
        let gpu = GpuConfig::c2050();
        let spec = BenchmarkApp::MM.spec();
        let small = slicing_overhead(&gpu, &spec, gpu.num_sms, 1);
        let large = slicing_overhead(&gpu, &spec, gpu.num_sms * 8, 1);
        assert!(small > large, "small={small} large={large}");
        assert!(small > 0.0);
    }

    #[test]
    fn min_slice_respects_budget() {
        let gpu = GpuConfig::c2050();
        let spec = BenchmarkApp::TEA.spec();
        let s = min_slice_size(&gpu, &spec, 2.0, 1);
        assert!(s >= gpu.num_sms);
        assert!(s < spec.grid_blocks);
        let ov = slicing_overhead(&gpu, &spec, s, 1);
        assert!(ov * 100.0 <= 2.5, "overhead={}", ov * 100.0); // small seed noise margin
    }

    #[test]
    fn kepler_allows_smaller_slices() {
        // Fig. 6: GTX680's cheap launches make nearly all slice sizes
        // viable; its minimum slice should be no larger (in SM
        // generations) than C2050's.
        let c = GpuConfig::c2050();
        let g = GpuConfig::gtx680();
        let spec = BenchmarkApp::BS.spec();
        let sc = min_slice_size(&c, &spec, 2.0, 1) / c.num_sms;
        let sg = min_slice_size(&g, &spec, 2.0, 1) / g.num_sms;
        assert!(sg <= sc, "kepler={sg} gens, fermi={sc} gens");
    }

    #[test]
    fn cache_returns_same() {
        let gpu = GpuConfig::gtx680();
        let cache = SliceSizeCache::new();
        let spec = BenchmarkApp::ST.spec();
        assert_eq!(cache.get(&gpu, &spec, 2.0), cache.get(&gpu, &spec, 2.0));
    }

    #[test]
    fn gated_lookup_pins_whole_grid_for_unsliceable() {
        let gpu = GpuConfig::c2050();
        let cache = SliceSizeCache::new();
        let spec = BenchmarkApp::TEA.spec();
        // Unsliceable: whole grid, regardless of budget, and nothing
        // is cached that a later sliceable query could pick up.
        assert_eq!(cache.get_gated(&gpu, &spec, 1e9, false), spec.grid_blocks);
        let open = cache.get_gated(&gpu, &spec, 1e9, true);
        assert_eq!(open, gpu.num_sms, "gate must not poison the cache");
    }

    #[test]
    fn budget_is_part_of_cache_key() {
        // Regression: the seed keyed only (gpu, kernel), so the first
        // caller's budget won for every later budget. A near-zero
        // budget admits no candidate (falls back to the whole grid); a
        // huge budget admits the very first (one SM generation). Both
        // queried through one cache must disagree.
        let gpu = GpuConfig::c2050();
        let cache = SliceSizeCache::new();
        let spec = BenchmarkApp::TEA.spec();
        let tight = cache.get(&gpu, &spec, 1e-9);
        let generous = cache.get(&gpu, &spec, 1e9);
        assert_eq!(tight, spec.grid_blocks, "tight budget must degenerate to non-sliced");
        assert_eq!(generous, gpu.num_sms, "generous budget must take the smallest candidate");
        assert_ne!(tight, generous, "budget ignored in the cache key");
        // And each budget's answer is itself cached stably.
        assert_eq!(cache.get(&gpu, &spec, 1e-9), tight);
        assert_eq!(cache.get(&gpu, &spec, 1e9), generous);
    }

    #[test]
    fn grid_is_part_of_cache_key() {
        // Trace replay can submit same-name kernels with overridden
        // grids; the whole-grid fallback makes the answer depend on the
        // grid, so the key must too.
        let gpu = GpuConfig::c2050();
        let cache = SliceSizeCache::new();
        let spec = BenchmarkApp::MM.spec();
        let tiny = spec.with_grid(gpu.num_sms);
        let a = cache.get(&gpu, &tiny, 1e-9);
        let b = cache.get(&gpu, &spec, 1e-9);
        assert_eq!(a, tiny.grid_blocks);
        assert_eq!(b, spec.grid_blocks);
        assert_ne!(a, b, "grid ignored in the cache key");
    }
}
