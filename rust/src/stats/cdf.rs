//! Empirical cumulative distribution functions (Fig. 14 of the paper)
//! and the nearest-rank percentile helper the QoS reports use.

/// Nearest-rank percentile of an **ascending-sorted** sample: the
/// smallest element `x` such that at least `⌈q·n⌉` samples are `≤ x`
/// (the same convention as [`Cdf::quantile`], without building a
/// [`Cdf`]). `None` on an empty sample; a single-element sample answers
/// that element for every `q`.
pub fn percentile(sorted: &[f64], q: f64) -> Option<f64> {
    assert!((0.0..=1.0).contains(&q), "percentile {q} out of [0,1]");
    if sorted.is_empty() {
        return None;
    }
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile input must be sorted ascending"
    );
    let n = sorted.len();
    let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
    Some(sorted[idx])
}

/// An empirical CDF over a sample of f64 observations.
#[derive(Debug, Clone)]
pub struct Cdf {
    sorted: Vec<f64>,
}

impl Cdf {
    /// Build a CDF from a sample. NaNs are rejected.
    pub fn new(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        assert!(samples.iter().all(|x| !x.is_nan()), "NaN in sample");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted: samples }
    }

    /// Fraction of the sample that is <= x.
    pub fn at(&self, x: f64) -> f64 {
        // partition_point = count of elements <= x via binary search.
        let count = self.sorted.partition_point(|&v| v <= x);
        count as f64 / self.sorted.len() as f64
    }

    /// The q-quantile (q in [0,1]) using nearest-rank (one formula for
    /// the whole crate: this delegates to [`percentile`]).
    pub fn quantile(&self, q: f64) -> f64 {
        percentile(&self.sorted, q).expect("Cdf is never empty")
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Whether the CDF holds no samples.
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Sample (x, F(x)) pairs at `points` evenly spaced x values — the
    /// series plotted in Fig. 14.
    pub fn series(&self, points: usize) -> Vec<(f64, f64)> {
        assert!(points >= 2);
        let (lo, hi) = (self.min(), self.max());
        (0..points)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (points - 1) as f64;
                (x, self.at(x))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_values() {
        let c = Cdf::new(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(1.0), 0.25);
        assert_eq!(c.at(2.5), 0.5);
        assert_eq!(c.at(4.0), 1.0);
        assert_eq!(c.at(9.0), 1.0);
    }

    #[test]
    fn quantiles() {
        let c = Cdf::new((1..=100).map(|i| i as f64).collect());
        assert_eq!(c.quantile(0.5), 50.0);
        assert_eq!(c.quantile(1.0), 100.0);
        assert_eq!(c.quantile(0.01), 1.0);
    }

    #[test]
    fn series_monotone() {
        let c = Cdf::new(vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let s = c.series(16);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(s.last().unwrap().1, 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        let _ = Cdf::new(vec![]);
    }

    #[test]
    fn percentile_empty_is_none() {
        assert_eq!(percentile(&[], 0.5), None);
        assert_eq!(percentile(&[], 0.0), None);
    }

    #[test]
    fn percentile_single_sample_answers_every_q() {
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&[4.2], q), Some(4.2), "q={q}");
        }
    }

    #[test]
    fn percentile_tied_samples() {
        let xs = [1.0, 2.0, 2.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 0.5), Some(2.0));
        assert_eq!(percentile(&xs, 0.2), Some(1.0));
        assert_eq!(percentile(&xs, 0.21), Some(2.0));
        assert_eq!(percentile(&xs, 1.0), Some(3.0));
        // All-tied: every percentile is the tie.
        assert_eq!(percentile(&[7.0; 9], 0.99), Some(7.0));
    }

    #[test]
    fn percentile_matches_cdf_quantile() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let c = Cdf::new(xs.clone());
        for q in [0.01, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&xs, q), Some(c.quantile(q)), "q={q}");
        }
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_out_of_range_q() {
        let _ = percentile(&[1.0], 1.5);
    }
}
