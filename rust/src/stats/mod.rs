//! Statistical utilities used across Kernelet.
//!
//! Everything here is dependency-free and deterministic: the scheduler,
//! the simulator and the benchmark harness all draw randomness from
//! [`rng::Xoshiro256`] seeded explicitly, so every figure and table in the
//! paper reproduction is bit-reproducible.

pub mod cdf;
pub mod regression;
pub mod rng;
pub mod summary;

pub use cdf::{percentile, Cdf};
pub use regression::{linear_fit, pearson};
pub use rng::{split_seed, Xoshiro256};
pub use summary::Summary;
