//! Correlation and linear regression.
//!
//! The paper uses a regression over profiler counters to identify PUR and
//! MUR as the factors most correlated with co-scheduling profit (§4.3,
//! Fig. 4); `pearson` and `linear_fit` regenerate that analysis.

/// Pearson correlation coefficient between two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Ordinary least squares fit y = a + b*x. Returns (intercept, slope, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let slope = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let intercept = my - slope * mx;
    // r^2 from residuals.
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        let pred = intercept + slope * x;
        ss_res += (y - pred) * (y - pred);
        ss_tot += (y - my) * (y - my);
    }
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (intercept, slope, r2)
}

/// Multiple linear regression y = b0 + b.x via normal equations.
///
/// `xs` is row-major: one row of predictors per observation. Returns the
/// coefficient vector [b0, b1, ..., bk]. Used by the pruning-factor
/// analysis to rank profiler counters against CP.
pub fn multi_linear_fit(xs: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let k = xs[0].len();
    let n = xs.len();
    assert!(n > k, "underdetermined system");
    let dim = k + 1;
    // Build X^T X and X^T y with an implicit leading 1 column.
    let mut ata = vec![vec![0.0f64; dim]; dim];
    let mut aty = vec![0.0f64; dim];
    for (row, &y) in xs.iter().zip(ys) {
        assert_eq!(row.len(), k);
        let mut aug = Vec::with_capacity(dim);
        aug.push(1.0);
        aug.extend_from_slice(row);
        for i in 0..dim {
            for j in 0..dim {
                ata[i][j] += aug[i] * aug[j];
            }
            aty[i] += aug[i] * y;
        }
    }
    solve_dense(&mut ata, &mut aty);
    aty
}

/// In-place Gaussian elimination with partial pivoting; solution left in b.
fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        for row in col + 1..n {
            if a[row][col].abs() > a[pivot][col].abs() {
                pivot = row;
            }
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        let diag = a[col][col];
        assert!(diag.abs() > 1e-12, "singular system");
        for row in 0..n {
            if row == col {
                continue;
            }
            let f = a[row][col] / diag;
            for j in col..n {
                a[row][j] -= f * a[col][j];
            }
            b[row] -= f * b[col];
        }
    }
    for i in 0..n {
        b[i] /= a[i][i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_positive() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let ys = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_negative() {
        let xs = vec![1.0, 2.0, 3.0];
        let ys = vec![3.0, 2.0, 1.0];
        assert!((pearson(&xs, &ys) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let xs = vec![1.0, 1.0, 1.0];
        let ys = vec![1.0, 2.0, 3.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        let ys: Vec<f64> = xs.iter().map(|x| 1.5 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.5).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn multi_linear_recovers_coefficients() {
        // y = 1 + 2*x1 - 3*x2 on a grid.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..5 {
            for j in 0..5 {
                let (x1, x2) = (i as f64, j as f64 * 0.5);
                xs.push(vec![x1, x2]);
                ys.push(1.0 + 2.0 * x1 - 3.0 * x2);
            }
        }
        let c = multi_linear_fit(&xs, &ys);
        assert!((c[0] - 1.0).abs() < 1e-8);
        assert!((c[1] - 2.0).abs() < 1e-8);
        assert!((c[2] + 3.0).abs() < 1e-8);
    }
}
