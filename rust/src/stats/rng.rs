//! Deterministic pseudo-random number generation.
//!
//! We implement xoshiro256** (Blackman & Vigna) rather than pulling in a
//! `rand` dependency: the build is fully offline, and determinism across
//! the whole reproduction (simulator, Poisson arrivals, Monte-Carlo
//! baseline) matters more than cryptographic quality.

/// Derive an independent sub-stream seed from a base seed and a stream
/// index, SplitMix-style: the (seed, index) pair goes through a full
/// splitmix64 finalizer round, so nearby indices land in unrelated
/// regions of the seed space. Sequential-seed schemes such as
/// `seed + i * CONST` leave the per-stream generators on one additive
/// lattice and their outputs visibly correlated; Monte-Carlo sampling
/// (`coordinator::baselines::run_monte_carlo`) needs independence.
pub fn split_seed(seed: u64, index: u64) -> u64 {
    let mut z = seed ^ index.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG with explicit seeding via splitmix64.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed (expanded with splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high-quality mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / lambda
    }

    /// Poisson-distributed sample with mean `lambda`.
    ///
    /// Knuth's method for small lambda, normal approximation for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let n = self.normal(lambda, lambda.sqrt());
            n.max(0.0).round() as u64
        }
    }

    /// Normally distributed sample (Box-Muller).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normally distributed sample: `exp(N(mu, sigma))`. Median is
    /// `exp(mu)`; heavy right tail grows with `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        assert!(sigma >= 0.0);
        self.normal(mu, sigma).exp()
    }

    /// Pareto-distributed sample with shape `alpha` and scale `xm`
    /// (support `[xm, ∞)`; mean is infinite for `alpha <= 1`).
    pub fn pareto(&mut self, alpha: f64, xm: f64) -> f64 {
        assert!(alpha > 0.0 && xm > 0.0);
        let u = 1.0 - self.f64(); // in (0, 1]: avoid div by zero
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Xoshiro256::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close_small_lambda() {
        let mut r = Xoshiro256::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn poisson_mean_close_large_lambda() {
        let mut r = Xoshiro256::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.poisson(100.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 100.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn lognormal_median_close() {
        let mut r = Xoshiro256::new(23);
        let n = 100_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(1.0, 0.8)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let median = xs[n / 2];
        let expect = 1.0f64.exp();
        assert!((median / expect - 1.0).abs() < 0.05, "median={median}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn pareto_support_and_tail() {
        let mut r = Xoshiro256::new(29);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.pareto(2.0, 1.5)).collect();
        assert!(xs.iter().all(|&x| x >= 1.5));
        // Median of Pareto(alpha, xm) is xm * 2^(1/alpha).
        let mut s = xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let expect = 1.5 * 2.0f64.powf(0.5);
        assert!((s[n / 2] / expect - 1.0).abs() < 0.05, "median={}", s[n / 2]);
        // Heavy tail: the max dwarfs the median.
        assert!(s[n - 1] > 10.0 * s[n / 2]);
    }

    #[test]
    fn split_seed_streams_distinct_and_deterministic() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000u64 {
            let s = split_seed(42, i);
            assert!(seen.insert(s), "collision at index {i}");
            assert_eq!(s, split_seed(42, i));
        }
        assert_ne!(split_seed(1, 0), split_seed(2, 0));
    }

    #[test]
    fn split_seed_decorrelates_first_draws() {
        // The first draw of consecutive sub-streams must not trend with
        // the index (the old `seed + i*CONST` scheme did).
        let draws: Vec<f64> =
            (0..500u64).map(|i| Xoshiro256::new(split_seed(7, i)).f64()).collect();
        let idx: Vec<f64> = (0..500).map(|i| i as f64).collect();
        let corr = crate::stats::pearson(&idx, &draws);
        assert!(corr.abs() < 0.15, "corr={corr}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
