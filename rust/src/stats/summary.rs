//! Streaming summary statistics (mean / variance / min / max).

/// Welford-style online summary of an f64 stream.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Summarize a whole slice.
    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    /// Samples folded in.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another summary into this one (parallel reduction).
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::from_slice(&xs);
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_whole() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut a = Summary::from_slice(&xs[..40]);
        let b = Summary::from_slice(&xs[40..]);
        a.merge(&b);
        let whole = Summary::from_slice(&xs);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
    }
}
