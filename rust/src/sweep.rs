//! Parallel sweep driver for the figure and bench harnesses.
//!
//! A sweep is a grid of independent cells — (scenario × load ×
//! fleet) engine runs whose seeds are derived per cell with
//! [`crate::stats::split_seed`], so no cell's result depends on any
//! other's. Running them on one thread serializes minutes of
//! simulation; this module fans the cells across a scoped thread pool
//! while keeping the *output* bit-identical to the serial loop:
//!
//! - Results are returned in **input order** (each worker tags results
//!   with the cell index; the driver re-assembles by index), so
//!   downstream report rows never depend on scheduling jitter.
//! - Workers share nothing but the cell function. Shared caches the
//!   function touches (the coordinator's sharded memo maps) only store
//!   deterministic pure-function results, so which thread populates an
//!   entry first cannot change any value read from it.
//!
//! `tests/hotpath_invariants.rs` pins the parallel driver byte-for-byte
//! against the serial loop on a real figure sweep.
//!
//! The pool is plain `std::thread::scope` with an atomic next-index
//! counter — the same idiom as `SimCache::prewarm_*` — because the
//! toolchain vendors no external crates (no rayon offline). Thread
//! count comes from [`std::thread::available_parallelism`], overridable
//! with the `KERNELET_SWEEP_THREADS` env var (`1` forces the serial
//! path, useful for profiling and differential tests).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Env var overriding the worker-thread count (parsed as `usize`;
/// values < 1 clamp to 1, unparsable values are ignored).
pub const THREADS_ENV: &str = "KERNELET_SWEEP_THREADS";

/// Worker count for a sweep of `cells` cells: the env override if set,
/// otherwise available parallelism, never more workers than cells.
pub fn sweep_threads(cells: usize) -> usize {
    let hw = || {
        std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1)
    };
    let n = match std::env::var(THREADS_ENV) {
        Ok(v) => v.trim().parse::<usize>().map(|n| n.max(1)).unwrap_or_else(|_| hw()),
        Err(_) => hw(),
    };
    n.min(cells.max(1))
}

/// Evaluate `f` over every cell and return the results **in input
/// order**, fanning across [`sweep_threads`] workers.
///
/// `f` receives `(index, &cell)` — the index is the cell's position in
/// `cells`, which callers typically fold into a per-cell seed. A panic
/// in any cell propagates to the caller (the sweep does not silently
/// drop cells).
pub fn run_cells<T, R, F>(cells: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    run_cells_with(cells, sweep_threads(cells.len()), f)
}

/// [`run_cells`] with an explicit worker count. `threads <= 1` runs
/// the plain serial loop on the calling thread (no pool, no atomics) —
/// the reference the parallel path is pinned against.
pub fn run_cells_with<T, R, F>(cells: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if threads <= 1 || cells.len() <= 1 {
        return cells.iter().enumerate().map(|(i, c)| f(i, c)).collect();
    }
    let workers = threads.min(cells.len());
    let next = AtomicUsize::new(0);
    let f = &f;
    let mut slots: Vec<Option<R>> = Vec::with_capacity(cells.len());
    slots.resize_with(cells.len(), || None);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    // Work stealing by atomic index: fast cells drain
                    // more of the grid, so one slow cell cannot leave
                    // the other workers idle behind a static partition.
                    let mut got: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= cells.len() {
                            break;
                        }
                        got.push((i, f(i, &cells[i])));
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("every claimed cell produces exactly one result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_single_cell_grids() {
        let none: Vec<u32> = run_cells(&[], |_, c: &u32| *c);
        assert!(none.is_empty());
        assert_eq!(run_cells(&[7u32], |i, c| (i, *c)), vec![(0, 7)]);
    }

    #[test]
    fn results_come_back_in_input_order() {
        // Uneven per-cell work so threads finish out of order; the
        // driver must still hand results back by input index.
        let cells: Vec<u64> = (0..64).collect();
        let out = run_cells_with(&cells, 8, |i, &c| {
            let mut acc = c;
            for _ in 0..((64 - i) * 1000) {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            (i as u64, c, acc)
        });
        assert_eq!(out.len(), 64);
        for (i, (idx, c, _)) in out.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*c, i as u64);
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let cells: Vec<u64> = (0..33).map(|i| i * 31 + 7).collect();
        let f = |i: usize, c: &u64| -> f64 {
            // Order-sensitive float accumulation inside one cell —
            // identical per cell, so the sweep result must match.
            let mut acc = 0.0f64;
            for k in 0..(*c % 17 + 3) {
                acc += 1.0 / (i as f64 + k as f64 + 1.5);
            }
            acc
        };
        let serial = run_cells_with(&cells, 1, f);
        let parallel = run_cells_with(&cells, 6, f);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn thread_count_never_exceeds_cells() {
        assert_eq!(sweep_threads(0), 1);
        assert_eq!(sweep_threads(1), 1);
        assert!(sweep_threads(4) <= 4);
    }
}
