//! Online arrival sources — streaming workload scenarios.
//!
//! The seed pre-materialized every workload as a sorted
//! [`Stream`](super::Stream) `Vec`, which can only express what fits in
//! memory and is known up front. An [`ArrivalSource`] is pulled by the
//! engine one arrival at a time ([`crate::coordinator::Engine::run_source`]),
//! which admits scenarios a pre-sorted `Vec` cannot:
//!
//! - [`PoissonSource`] — the paper's Table 5 mixes, streamed. Kept
//!   **bit-identical** to [`Stream::poisson`](super::Stream::poisson)
//!   (same RNG draw order, same ids, same tie-breaking) so the frozen
//!   `Vec` path remains the differential oracle.
//! - [`BurstySource`] — Markov-modulated Poisson (calm/burst states
//!   with exponential sojourns): the diurnal-scale "thundering herd".
//! - [`DiurnalSource`] — sinusoidal rate curve sampled by thinning.
//! - [`HeavyTailSource`] — Poisson arrivals whose *service demand* is
//!   heavy-tailed: grids scaled by a bucketed Pareto factor.
//! - [`ClosedLoopSource`] — N clients with exponential think time;
//!   arrivals depend on completions via [`ArrivalSource::on_completion`].
//! - [`ReplaySource`] — any prebuilt instance list, including JSON
//!   traces via [`parse_trace`].
//!
//! All sources draw from the crate's deterministic
//! [`Xoshiro256`](crate::stats::Xoshiro256), so every scenario is
//! reproducible from its seed.
//!
//! Every source accepts a [`QosMix`] (`with_qos` builder) and stamps
//! class/deadline annotations on its arrivals **at emission time,
//! without consuming RNG** — so a [`QosMix::ALL_BATCH`] source is
//! bit-identical to an un-annotated one, and any other mix changes only
//! the [`Qos`] labels, never the arrival sequence. The JSON trace
//! format round-trips the annotations ([`parse_trace`] /
//! [`write_trace`]).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::{Mix, QosMix, Stream};
use crate::kernel::{BenchmarkApp, KernelInstance, KernelSpec, Qos, ServiceClass};
use crate::stats::Xoshiro256;

/// An online arrival process. The engine *pulls*: it peeks the next
/// arrival time to know how far to run, pops the instance when the
/// clock gets there, and pushes completions back for closed-loop
/// sources.
///
/// Contract: [`peek_time`](Self::peek_time) returns the time of the
/// instance the next [`next_arrival`](Self::next_arrival) call will
/// yield. A source may answer `None` while earlier submissions are
/// still in flight (closed-loop clients all waiting), but once the
/// device is idle *and* all completions have been delivered, `None`
/// means exhausted.
pub trait ArrivalSource {
    /// Scenario name (reports, benches, traces).
    fn scenario(&self) -> &'static str;

    /// Arrival time (seconds) of the next instance, if one is
    /// currently scheduled.
    fn peek_time(&self) -> Option<f64>;

    /// Pop the next instance (the one [`Self::peek_time`] described).
    fn next_arrival(&mut self) -> Option<KernelInstance>;

    /// Completion feedback: instance `id` finished at `t_secs`.
    /// Open-loop sources ignore it.
    fn on_completion(&mut self, _id: u64, _t_secs: f64) {}

    /// Shed feedback — client-visible backpressure: instance `id` was
    /// rejected by admission control (at the gate, the router or a
    /// device) at `t_secs` and will never run. Open-loop sources ignore
    /// it; [`ClosedLoopSource`] re-queues the client with a capped,
    /// jittered retry instead of losing it permanently.
    fn on_shed(&mut self, _id: u64, _t_secs: f64) {}

    /// Number of shed submissions the source has re-queued for retry so
    /// far (0 for sources without retry semantics).
    fn retries(&self) -> u64 {
        0
    }

    /// Whether the source may still produce arrivals (drives the solo
    /// dispatcher's chunk-vs-run-whole decision). The default treats a
    /// scheduled arrival as the only evidence; closed-loop sources
    /// override with their remaining-job count.
    fn more_expected(&self) -> bool {
        self.peek_time().is_some()
    }
}

// ---------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------

/// Streams a prebuilt instance list (a [`Stream`], a parsed trace, a
/// hand-rolled test fixture) in order.
pub struct ReplaySource {
    name: &'static str,
    instances: Vec<KernelInstance>,
    cursor: usize,
}

impl ReplaySource {
    /// Replay a pre-materialized [`Stream`] in order.
    pub fn from_stream(stream: &Stream) -> Self {
        Self::from_instances("replay", stream.instances.clone())
    }

    /// `instances` must be sorted by arrival time (a [`Stream`] is).
    pub fn from_instances(name: &'static str, instances: Vec<KernelInstance>) -> Self {
        for w in instances.windows(2) {
            debug_assert!(w[0].arrival_time <= w[1].arrival_time, "replay not sorted");
        }
        Self { name, instances, cursor: 0 }
    }

    /// Re-stamp the replayed instances with a QoS mix (by instance id).
    /// [`QosMix::ALL_BATCH`] is a no-op so annotations already carried
    /// by a parsed trace are preserved.
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        if !qos.is_all_batch() {
            for k in &mut self.instances {
                k.qos = qos.stamp(k.id, k.arrival_time);
            }
        }
        self
    }
}

impl ArrivalSource for ReplaySource {
    fn scenario(&self) -> &'static str {
        self.name
    }

    fn peek_time(&self) -> Option<f64> {
        self.instances.get(self.cursor).map(|k| k.arrival_time)
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let k = self.instances.get(self.cursor).cloned();
        if k.is_some() {
            self.cursor += 1;
        }
        k
    }
}

// ---------------------------------------------------------------------
// Poisson (bit-identical to the frozen Vec path)
// ---------------------------------------------------------------------

/// The paper's Poisson mixes as a stream: a lazy k-way merge over the
/// per-application arrival processes.
///
/// RNG consumption is *identical* to [`Stream::poisson`] — one
/// generator, drawn application-major — and the merge tie-breaks the
/// way that path's stable sort does (lower application index first), so
/// ids, times and order match the frozen `Vec` bit-for-bit. Only the
/// per-application arrival times are buffered; instances are
/// constructed lazily as the engine pulls.
pub struct PoissonSource {
    specs: Vec<KernelSpec>,
    times: Vec<Vec<f64>>,
    cursors: Vec<usize>,
    per_app: u32,
    qos: QosMix,
}

impl PoissonSource {
    /// `per_app` arrivals per application at per-app rate `lambda`
    /// (arrivals/sec), drawn exactly like [`Stream::poisson`].
    pub fn new(mix: Mix, per_app: u32, lambda: f64, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let specs: Vec<KernelSpec> = mix.apps().iter().map(|a| a.spec()).collect();
        let times: Vec<Vec<f64>> = specs
            .iter()
            .map(|_| {
                let mut t = 0.0f64;
                (0..per_app)
                    .map(|_| {
                        t += rng.exponential(lambda);
                        t
                    })
                    .collect()
            })
            .collect();
        Self { cursors: vec![0; specs.len()], specs, times, per_app, qos: QosMix::ALL_BATCH }
    }

    /// Stamp arrivals with a QoS mix (emission-time, RNG-free — the
    /// arrival sequence stays bit-identical to the frozen `Vec` path).
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        self.qos = qos;
        self
    }

    /// Index of the app whose head arrival is earliest. Strict `<`
    /// keeps the lowest app index on ties — exactly what the frozen
    /// path's stable sort over app-major generation order does.
    fn head(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (a, &cur) in self.cursors.iter().enumerate() {
            if let Some(&t) = self.times[a].get(cur) {
                if best.map_or(true, |(_, bt)| t < bt) {
                    best = Some((a, t));
                }
            }
        }
        best.map(|(a, _)| a)
    }
}

impl ArrivalSource for PoissonSource {
    fn scenario(&self) -> &'static str {
        "poisson"
    }

    fn peek_time(&self) -> Option<f64> {
        self.head().map(|a| self.times[a][self.cursors[a]])
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let a = self.head()?;
        let k = self.cursors[a];
        self.cursors[a] += 1;
        // Same id scheme as the frozen path: app-major, then arrival.
        let id = a as u64 * self.per_app as u64 + k as u64;
        let t = self.times[a][k];
        Some(KernelInstance::new(id, self.specs[a].clone(), t).with_qos(self.qos.stamp(id, t)))
    }
}

// ---------------------------------------------------------------------
// Markov-modulated (bursty)
// ---------------------------------------------------------------------

/// Two-state Markov-modulated Poisson process: the arrival rate jumps
/// between a calm and a burst state, with exponentially distributed
/// sojourns in each. Both the arrival draws and the state switches are
/// memoryless, so interleaving them by competing exponentials is exact.
pub struct BurstySource {
    specs: Vec<KernelSpec>,
    rng: Xoshiro256,
    total: u64,
    emitted: u64,
    /// Arrival rate (kernels/sec) in each state.
    rates: [f64; 2],
    /// Mean sojourn (sec) in each state.
    sojourn_secs: [f64; 2],
    state: usize,
    sojourn_left: f64,
    t: f64,
    pending: Option<KernelInstance>,
    qos: QosMix,
}

impl BurstySource {
    /// `total` arrivals from a 2-state MMPP with per-state rates and
    /// mean sojourns.
    pub fn new(mix: Mix, total: u64, rates: [f64; 2], sojourn_secs: [f64; 2], seed: u64) -> Self {
        assert!(rates[0] > 0.0 && rates[1] > 0.0);
        assert!(sojourn_secs[0] > 0.0 && sojourn_secs[1] > 0.0);
        let mut rng = Xoshiro256::new(seed);
        let sojourn_left = rng.exponential(1.0 / sojourn_secs[0]);
        let mut src = Self {
            specs: mix.apps().iter().map(|a| a.spec()).collect(),
            rng,
            total,
            emitted: 0,
            rates,
            sojourn_secs,
            state: 0,
            sojourn_left,
            t: 0.0,
            pending: None,
            qos: QosMix::ALL_BATCH,
        };
        src.pending = src.generate();
        src
    }

    /// Stamp arrivals with a QoS mix (emission-time, RNG-free).
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        self.qos = qos;
        self
    }

    fn generate(&mut self) -> Option<KernelInstance> {
        if self.emitted == self.total {
            return None;
        }
        loop {
            let dt = self.rng.exponential(self.rates[self.state]);
            if dt < self.sojourn_left {
                self.sojourn_left -= dt;
                self.t += dt;
                let spec = self.rng.choose(&self.specs).clone();
                let id = self.emitted;
                self.emitted += 1;
                return Some(KernelInstance::new(id, spec, self.t));
            }
            // State switch fires first; restart the (memoryless)
            // arrival draw in the new state.
            self.t += self.sojourn_left;
            self.state = 1 - self.state;
            self.sojourn_left = self.rng.exponential(1.0 / self.sojourn_secs[self.state]);
        }
    }
}

impl ArrivalSource for BurstySource {
    fn scenario(&self) -> &'static str {
        "bursty"
    }

    fn peek_time(&self) -> Option<f64> {
        self.pending.as_ref().map(|k| k.arrival_time)
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let out = self.pending.take();
        if out.is_some() {
            self.pending = self.generate();
        }
        out.map(|k| {
            let q = self.qos.stamp(k.id, k.arrival_time);
            k.with_qos(q)
        })
    }
}

// ---------------------------------------------------------------------
// Diurnal
// ---------------------------------------------------------------------

/// Sinusoidal rate curve λ(t) = base · (1 + amp · sin(2πt/period)),
/// sampled exactly by thinning a Poisson process at λ_max. A
/// flash-crowd surge window ([`DiurnalSource::with_surge`]) can
/// multiply the instantaneous rate inside a timed interval — the
/// arrival-side half of the fleet resilience drills.
pub struct DiurnalSource {
    specs: Vec<KernelSpec>,
    rng: Xoshiro256,
    total: u64,
    emitted: u64,
    base: f64,
    amp: f64,
    period: f64,
    lambda_max: f64,
    t: f64,
    pending: Option<KernelInstance>,
    qos: QosMix,
    /// Flash-crowd window `(start_secs, duration_secs, factor)`;
    /// `None` (the default) leaves every draw bit-identical to the
    /// surge-free source.
    surge: Option<(f64, f64, f64)>,
}

impl DiurnalSource {
    /// `total` arrivals from λ(t) = `base`·(1 + `amp`·sin(2πt/`period`)).
    pub fn new(mix: Mix, total: u64, base: f64, amp: f64, period: f64, seed: u64) -> Self {
        assert!(base > 0.0 && period > 0.0);
        assert!((0.0..1.0).contains(&amp), "amp must be in [0,1) so the rate stays positive");
        let mut src = Self {
            specs: mix.apps().iter().map(|a| a.spec()).collect(),
            rng: Xoshiro256::new(seed),
            total,
            emitted: 0,
            base,
            amp,
            period,
            lambda_max: base * (1.0 + amp),
            t: 0.0,
            pending: None,
            qos: QosMix::ALL_BATCH,
            surge: None,
        };
        src.pending = src.generate();
        src
    }

    /// Stamp arrivals with a QoS mix (emission-time, RNG-free).
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        self.qos = qos;
        self
    }

    /// Layer a flash-crowd surge on the diurnal curve (builder):
    /// inside `[at_secs, at_secs + duration_secs)` the instantaneous
    /// rate is multiplied by `factor`. The thinning bound is raised to
    /// cover the surged peak, so sampling stays exact over the window.
    /// Call right after construction: the one pre-drawn head arrival
    /// was thinned against the un-surged bound (exact whenever it
    /// precedes the window, which a mid-run surge guarantees).
    pub fn with_surge(mut self, at_secs: f64, duration_secs: f64, factor: f64) -> Self {
        assert!(at_secs >= 0.0 && duration_secs > 0.0, "bad surge window");
        assert!(
            factor >= 1.0 && factor.is_finite(),
            "surge factor {factor} < 1 would be a lull, not a crowd"
        );
        self.surge = Some((at_secs, duration_secs, factor));
        self.lambda_max = self.base * (1.0 + self.amp) * factor;
        self
    }

    fn rate_at(&self, t: f64) -> f64 {
        let diurnal =
            self.base * (1.0 + self.amp * (2.0 * std::f64::consts::PI * t / self.period).sin());
        match self.surge {
            Some((at, dur, factor)) if t >= at && t < at + dur => diurnal * factor,
            _ => diurnal,
        }
    }

    fn generate(&mut self) -> Option<KernelInstance> {
        if self.emitted == self.total {
            return None;
        }
        loop {
            self.t += self.rng.exponential(self.lambda_max);
            if self.rng.f64() * self.lambda_max < self.rate_at(self.t) {
                let spec = self.rng.choose(&self.specs).clone();
                let id = self.emitted;
                self.emitted += 1;
                return Some(KernelInstance::new(id, spec, self.t));
            }
        }
    }
}

impl ArrivalSource for DiurnalSource {
    fn scenario(&self) -> &'static str {
        if self.surge.is_some() {
            "flashcrowd"
        } else {
            "diurnal"
        }
    }

    fn peek_time(&self) -> Option<f64> {
        self.pending.as_ref().map(|k| k.arrival_time)
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let out = self.pending.take();
        if out.is_some() {
            self.pending = self.generate();
        }
        out.map(|k| {
            let q = self.qos.stamp(k.id, k.arrival_time);
            k.with_qos(q)
        })
    }
}

// ---------------------------------------------------------------------
// Heavy-tailed service demand
// ---------------------------------------------------------------------

/// Grid-size multipliers for the heavy-tail buckets. Bucketing keeps
/// the kernel population finite so the measurement caches stay warm
/// (each bucket is a distinct named kernel variant).
const HEAVY_TAIL_BUCKETS: [u32; 4] = [1, 2, 4, 8];

/// Intern a scaled-variant kernel name (`"MMx4"`). `KernelSpec.name`
/// is `&'static str`, so the string must be leaked — interning in a
/// process-wide registry bounds the leak to one allocation per
/// (benchmark, multiplier) pair no matter how many sources a
/// long-lived process constructs.
fn variant_name(base: &'static str, m: u32) -> &'static str {
    use std::sync::{Mutex, OnceLock};
    static INTERN: OnceLock<Mutex<HashMap<(&'static str, u32), &'static str>>> = OnceLock::new();
    let mut map = INTERN.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    *map.entry((base, m))
        .or_insert_with(|| Box::leak(format!("{base}x{m}").into_boxed_str()))
}

/// Poisson arrivals whose *service demand* is heavy-tailed: each
/// arrival's grid is scaled by `2^⌊log2(Pareto(alpha))⌋`, clamped to the
/// bucket set — most kernels are base-sized, a tail is 8× elephants.
pub struct HeavyTailSource {
    variants: Vec<KernelSpec>, // apps × buckets, app-major
    buckets: usize,
    rng: Xoshiro256,
    lambda: f64,
    alpha: f64,
    total: u64,
    emitted: u64,
    t: f64,
    pending: Option<KernelInstance>,
    qos: QosMix,
}

impl HeavyTailSource {
    /// `total` Poisson arrivals at rate `lambda` whose grids scale by
    /// a bucketed Pareto(`alpha`) factor.
    pub fn new(mix: Mix, total: u64, lambda: f64, alpha: f64, seed: u64) -> Self {
        assert!(lambda > 0.0 && alpha > 0.0);
        let mut variants = Vec::new();
        for app in mix.apps() {
            let base = app.spec();
            for &m in &HEAVY_TAIL_BUCKETS {
                let mut s = base.with_grid(base.grid_blocks * m);
                if m > 1 {
                    s.name = variant_name(base.name, m);
                }
                variants.push(s);
            }
        }
        let mut src = Self {
            variants,
            buckets: HEAVY_TAIL_BUCKETS.len(),
            rng: Xoshiro256::new(seed),
            lambda,
            alpha,
            total,
            emitted: 0,
            t: 0.0,
            pending: None,
            qos: QosMix::ALL_BATCH,
        };
        src.pending = src.generate();
        src
    }

    /// Stamp arrivals with a QoS mix (emission-time, RNG-free).
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        self.qos = qos;
        self
    }

    fn generate(&mut self) -> Option<KernelInstance> {
        if self.emitted == self.total {
            return None;
        }
        self.t += self.rng.exponential(self.lambda);
        let napps = self.variants.len() / self.buckets;
        let app = self.rng.index(napps);
        let factor = self.rng.pareto(self.alpha, 1.0);
        let bucket = (factor.log2().floor() as i64).clamp(0, self.buckets as i64 - 1) as usize;
        let spec = self.variants[app * self.buckets + bucket].clone();
        let id = self.emitted;
        self.emitted += 1;
        Some(KernelInstance::new(id, spec, self.t))
    }
}

impl ArrivalSource for HeavyTailSource {
    fn scenario(&self) -> &'static str {
        "heavytail"
    }

    fn peek_time(&self) -> Option<f64> {
        self.pending.as_ref().map(|k| k.arrival_time)
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let out = self.pending.take();
        if out.is_some() {
            self.pending = self.generate();
        }
        out.map(|k| {
            let q = self.qos.stamp(k.id, k.arrival_time);
            k.with_qos(q)
        })
    }
}

// ---------------------------------------------------------------------
// Closed loop
// ---------------------------------------------------------------------

/// How many consecutive sheds a closed-loop client retries before it
/// gives up its current submission for good.
const MAX_SHED_RETRIES: u32 = 5;

/// N clients, each cycling submit → wait for completion → think
/// (exponential) → resubmit, until `total` jobs have been issued
/// fleet-wide. The offered load self-throttles with service time — the
/// canonical interactive-user model.
///
/// Backpressure: a shed submission ([`ArrivalSource::on_shed`]) is
/// retried — the client re-enters think state with a fresh jittered
/// think draw and resubmits under a new id, up to [`MAX_SHED_RETRIES`]
/// consecutive sheds (a completion resets the strike count). The source
/// used to drop such clients permanently; [`Self::retries`] counts the
/// re-queues so reports can surface them.
pub struct ClosedLoopSource {
    specs: Vec<KernelSpec>,
    rng: Xoshiro256,
    think_rate: f64,
    total: u64,
    /// Jobs charged against `total` (a retried shed returns its slot).
    issued: u64,
    /// Monotone id counter — never reused, so a retry is a fresh id.
    next_id: u64,
    /// (next submit time, client) for clients currently thinking.
    thinking: Vec<(f64, usize)>,
    /// instance id → owning client, for jobs in flight.
    owner: HashMap<u64, usize>,
    /// Consecutive sheds per client since its last completion.
    strikes: Vec<u32>,
    retried: u64,
    qos: QosMix,
}

impl ClosedLoopSource {
    /// `clients` clients with exponential think time at `think_rate`
    /// (thinks/sec), issuing `total` jobs fleet-wide.
    pub fn new(mix: Mix, clients: usize, think_rate: f64, total: u64, seed: u64) -> Self {
        assert!(clients >= 1 && think_rate > 0.0);
        let mut rng = Xoshiro256::new(seed);
        let thinking = (0..clients).map(|c| (rng.exponential(think_rate), c)).collect();
        Self {
            specs: mix.apps().iter().map(|a| a.spec()).collect(),
            rng,
            think_rate,
            total,
            issued: 0,
            next_id: 0,
            thinking,
            owner: HashMap::new(),
            strikes: vec![0; clients],
            retried: 0,
            qos: QosMix::ALL_BATCH,
        }
    }

    /// Stamp arrivals with a QoS mix (emission-time, RNG-free).
    pub fn with_qos(mut self, qos: QosMix) -> Self {
        self.qos = qos;
        self
    }

    fn head(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, &(t, _)) in self.thinking.iter().enumerate() {
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((i, t));
            }
        }
        best.map(|(i, _)| i)
    }
}

impl ArrivalSource for ClosedLoopSource {
    fn scenario(&self) -> &'static str {
        "closed"
    }

    fn peek_time(&self) -> Option<f64> {
        if self.issued >= self.total {
            return None;
        }
        self.head().map(|i| self.thinking[i].0)
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        if self.issued >= self.total {
            return None;
        }
        let i = self.head()?;
        let (t, client) = self.thinking.remove(i);
        let id = self.next_id;
        self.next_id += 1;
        self.issued += 1;
        self.owner.insert(id, client);
        let spec = self.rng.choose(&self.specs).clone();
        Some(KernelInstance::new(id, spec, t).with_qos(self.qos.stamp(id, t)))
    }

    fn on_completion(&mut self, id: u64, t_secs: f64) {
        if let Some(client) = self.owner.remove(&id) {
            self.strikes[client] = 0;
            if self.issued < self.total {
                self.thinking.push((t_secs + self.rng.exponential(self.think_rate), client));
            }
        }
    }

    fn on_shed(&mut self, id: u64, t_secs: f64) {
        if let Some(client) = self.owner.remove(&id) {
            self.strikes[client] += 1;
            if self.strikes[client] <= MAX_SHED_RETRIES {
                // Return the budget slot and resubmit after a jittered
                // think — the retry is a fresh id, never a reused one.
                self.issued -= 1;
                self.retried += 1;
                self.thinking.push((t_secs + self.rng.exponential(self.think_rate), client));
            }
            // Past the cap the client abandons this submission: the
            // shed stays terminal, exactly the pre-retry behavior.
        }
    }

    fn retries(&self) -> u64 {
        self.retried
    }

    fn more_expected(&self) -> bool {
        // A client that exhausted its shed retries is gone for good; if
        // every client gave up, no budget slot can ever be filled.
        self.issued < self.total && (!self.thinking.is_empty() || !self.owner.is_empty())
    }
}

// ---------------------------------------------------------------------
// JSON trace replay
// ---------------------------------------------------------------------

/// Parse a submission trace: a JSON array of flat objects
///
/// ```json
/// [
///   {"app": "MM", "t": 0.0},
///   {"app": "PC", "t": 0.5, "grid": 512, "class": "latency", "deadline": 1.5}
/// ]
/// ```
///
/// `app` is a Table 3 benchmark name, `t` the arrival time in seconds,
/// `grid` an optional grid-size override, `class` an optional service
/// class (`"latency"` / `"batch"`, default batch) and `deadline` an
/// optional absolute completion deadline in seconds (same clock as
/// `t`). Ids follow file order; instances are then sorted (stably) by
/// arrival time. The parser is deliberately minimal — serde is
/// unavailable offline.
pub fn parse_trace(src: &str) -> Result<Vec<KernelInstance>> {
    let mut p = JsonCursor { b: src.as_bytes(), i: 0 };
    p.ws();
    p.expect(b'[')?;
    let mut instances = Vec::new();
    p.ws();
    if p.peek() == Some(b']') {
        p.i += 1;
    } else {
        loop {
            let obj = p.object().with_context(|| format!("trace entry {}", instances.len()))?;
            let mut app: Option<String> = None;
            let mut t: Option<f64> = None;
            let mut grid: Option<f64> = None;
            let mut class: Option<String> = None;
            let mut deadline: Option<f64> = None;
            for (k, v) in obj {
                match (k.as_str(), v) {
                    ("app", JsonVal::Str(s)) => app = Some(s),
                    ("t", JsonVal::Num(x)) => t = Some(x),
                    ("grid", JsonVal::Num(x)) => grid = Some(x),
                    ("class", JsonVal::Str(s)) => class = Some(s),
                    ("deadline", JsonVal::Num(x)) => deadline = Some(x),
                    (other, _) => bail!("unknown or mistyped trace field {other:?}"),
                }
            }
            let app = app.context("trace entry missing \"app\"")?;
            let t = t.context("trace entry missing \"t\"")?;
            if !t.is_finite() || t < 0.0 {
                bail!("trace arrival time {t} out of range");
            }
            let bench = BenchmarkApp::from_name(&app)
                .with_context(|| format!("unknown benchmark {app:?}"))?;
            let mut spec = bench.spec();
            if let Some(g) = grid {
                if g < 1.0 || g > u32::MAX as f64 || g.fract() != 0.0 {
                    bail!("trace grid {g} is not a positive integer");
                }
                spec = spec.with_grid(g as u32);
            }
            let class = match class.as_deref() {
                None => ServiceClass::Batch,
                Some(s) => ServiceClass::from_name(s)
                    .with_context(|| format!("unknown service class {s:?}"))?,
            };
            if let Some(d) = deadline {
                if !d.is_finite() || d < t {
                    bail!("trace deadline {d} precedes arrival {t} (or is not finite)");
                }
            }
            let qos = Qos { class, deadline };
            instances.push(
                KernelInstance::new(instances.len() as u64, spec, t).with_qos(qos),
            );
            p.ws();
            match p.next_byte()? {
                b',' => p.ws(),
                b']' => break,
                other => bail!("expected ',' or ']', found {:?}", other as char),
            }
        }
    }
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage after trace array");
    }
    instances.sort_by(|a, b| a.arrival_time.total_cmp(&b.arrival_time));
    Ok(instances)
}

/// Serialize instances to the JSON trace format [`parse_trace`] reads —
/// the `kernelet trace record` artifact.
///
/// Specs must be benchmark applications, possibly grid-scaled: a
/// heavy-tail variant like `"MMx8"` is written as its base app with the
/// (already scaled) grid as an override, which is exactly how the trace
/// format expresses scaled grids (the replayed instance keeps the base
/// name, so model caches treat it as the base application — the same
/// semantics a hand-written `"grid"` override has always had).
pub fn write_trace(instances: &[KernelInstance]) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::from("[\n");
    for (i, k) in instances.iter().enumerate() {
        let (app, write_grid) = match BenchmarkApp::from_name(k.spec.name) {
            Some(bench) => (bench.name(), k.spec.grid_blocks != bench.spec().grid_blocks),
            None => {
                // Heavy-tail bucket variant: "<base>x<multiplier>".
                let base = k
                    .spec
                    .name
                    .rsplit_once('x')
                    .and_then(|(base, m)| {
                        m.parse::<u32>().ok()?;
                        BenchmarkApp::from_name(base)
                    })
                    .with_context(|| {
                        format!("kernel {}: {:?} is not a benchmark app", k.id, k.spec.name)
                    })?;
                (base.name(), true)
            }
        };
        write!(out, "  {{\"app\": \"{app}\", \"t\": {}", k.arrival_time).unwrap();
        if write_grid {
            write!(out, ", \"grid\": {}", k.spec.grid_blocks).unwrap();
        }
        if k.qos.class == ServiceClass::Latency {
            write!(out, ", \"class\": \"{}\"", k.qos.class.name()).unwrap();
        }
        if let Some(d) = k.qos.deadline {
            write!(out, ", \"deadline\": {d}").unwrap();
        }
        out.push('}');
        if i + 1 < instances.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    Ok(out)
}

/// Tees every arrival popped from the wrapped source into a log.
/// `kernelet trace record` drives a normal engine run through this and
/// dumps the log — so completion-driven (closed-loop) scenarios record
/// the arrival sequence their run actually realized, and open-loop
/// scenarios record their policy-independent sequence.
pub struct RecordingSource<'a> {
    inner: &'a mut dyn ArrivalSource,
    log: Vec<KernelInstance>,
}

impl<'a> RecordingSource<'a> {
    /// Wrap `inner`, logging every popped arrival.
    pub fn new(inner: &'a mut dyn ArrivalSource) -> Self {
        Self { inner, log: Vec::new() }
    }

    /// The arrivals popped so far, in emission order.
    pub fn into_log(self) -> Vec<KernelInstance> {
        self.log
    }
}

impl ArrivalSource for RecordingSource<'_> {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }

    fn peek_time(&self) -> Option<f64> {
        self.inner.peek_time()
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let k = self.inner.next_arrival();
        if let Some(k) = &k {
            self.log.push(k.clone());
        }
        k
    }

    fn on_completion(&mut self, id: u64, t_secs: f64) {
        self.inner.on_completion(id, t_secs);
    }

    fn on_shed(&mut self, id: u64, t_secs: f64) {
        self.inner.on_shed(id, t_secs);
    }

    fn retries(&self) -> u64 {
        self.inner.retries()
    }

    fn more_expected(&self) -> bool {
        self.inner.more_expected()
    }
}

/// Parse a JSON trace straight into a [`ReplaySource`].
pub fn trace_source(src: &str) -> Result<ReplaySource> {
    Ok(ReplaySource::from_instances("trace", parse_trace(src)?))
}

enum JsonVal {
    Str(String),
    Num(f64),
}

/// Just enough JSON for [`parse_trace`]: arrays of flat objects whose
/// values are strings or numbers.
struct JsonCursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> JsonCursor<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next_byte(&mut self) -> Result<u8> {
        let c = self.peek().context("unexpected end of trace JSON")?;
        self.i += 1;
        Ok(c)
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        let got = self.next_byte()?;
        if got != want {
            bail!("expected {:?}, found {:?}", want as char, got as char);
        }
        Ok(())
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            if c == b'\\' {
                bail!("escape sequences are not supported in trace strings");
            }
            if c == b'"' {
                let s = std::str::from_utf8(&self.b[start..self.i])
                    .context("non-UTF8 trace string")?
                    .to_string();
                self.i += 1;
                return Ok(s);
            }
            self.i += 1;
        }
        bail!("unterminated string in trace JSON")
    }

    fn number(&mut self) -> Result<f64> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .with_context(|| format!("bad number at byte {start}"))
    }

    fn value(&mut self) -> Result<JsonVal> {
        self.ws();
        match self.peek().context("unexpected end of trace JSON")? {
            b'"' => Ok(JsonVal::Str(self.string()?)),
            _ => Ok(JsonVal::Num(self.number()?)),
        }
    }

    fn object(&mut self) -> Result<Vec<(String, JsonVal)>> {
        self.ws();
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.next_byte()? {
                b',' => continue,
                b'}' => return Ok(out),
                other => bail!("expected ',' or '}}', found {:?}", other as char),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Scenario factory
// ---------------------------------------------------------------------

/// Names accepted by [`scenario_source`].
pub const SCENARIO_NAMES: [&str; 7] =
    ["saturated", "poisson", "bursty", "diurnal", "heavytail", "closed", "flashcrowd"];

/// Build a named scenario over `mix` offering roughly `agg_rate_kps`
/// kernels/sec in aggregate, with `per_app` instances per application
/// (total = per_app × |apps|), arrivals stamped with `qos`
/// ([`QosMix::ALL_BATCH`] for the QoS-agnostic workloads). The one
/// factory the CLI, the saturation figure and the throughput/QoS
/// benches all share, so a scenario name means the same workload
/// everywhere.
pub fn scenario_source(
    scenario: &str,
    mix: Mix,
    per_app: u32,
    agg_rate_kps: f64,
    seed: u64,
    qos: QosMix,
) -> Result<Box<dyn ArrivalSource>> {
    let apps = mix.apps().len();
    let total = per_app as u64 * apps as u64;
    if scenario != "saturated" {
        anyhow::ensure!(agg_rate_kps > 0.0, "scenario {scenario} needs a positive arrival rate");
    }
    Ok(match scenario {
        "saturated" => Box::new(
            ReplaySource::from_stream(&Stream::saturated(mix, per_app, seed)).with_qos(qos),
        ),
        "poisson" => Box::new(
            PoissonSource::new(mix, per_app, agg_rate_kps / apps as f64, seed).with_qos(qos),
        ),
        // Calm at half the offered rate, bursts at 1.5× — equal mean
        // sojourns of ~20 arrivals keep the long-run rate at the target.
        "bursty" => Box::new(
            BurstySource::new(
                mix,
                total,
                [0.5 * agg_rate_kps, 1.5 * agg_rate_kps],
                [20.0 / agg_rate_kps, 20.0 / agg_rate_kps],
                seed,
            )
            .with_qos(qos),
        ),
        // ~3 day/night cycles over the run's expected span (the max(1)
        // keeps the period positive for a zero-instance scenario, whose
        // sinusoid never gets sampled anyway).
        "diurnal" => Box::new(
            DiurnalSource::new(
                mix,
                total,
                agg_rate_kps,
                0.8,
                ((total.max(1)) as f64 / agg_rate_kps) / 3.0,
                seed,
            )
            .with_qos(qos),
        ),
        "heavytail" => {
            Box::new(HeavyTailSource::new(mix, total, agg_rate_kps, 1.1, seed).with_qos(qos))
        }
        // The diurnal curve with a flash-crowd layered on: 3× the
        // instantaneous rate across the middle fifth of the run's
        // expected span — the arrival-side fleet-resilience drill.
        "flashcrowd" => {
            let span = total.max(1) as f64 / agg_rate_kps;
            Box::new(
                DiurnalSource::new(mix, total, agg_rate_kps, 0.8, span / 3.0, seed)
                    .with_surge(0.4 * span, 0.2 * span, 3.0)
                    .with_qos(qos),
            )
        }
        // 8 clients whose think-limited aggregate rate is the target;
        // service time then throttles the realized rate below it.
        "closed" => Box::new(
            ClosedLoopSource::new(mix, 8, agg_rate_kps / 8.0, total, seed).with_qos(qos),
        ),
        other => bail!("unknown scenario {other} (valid: {})", SCENARIO_NAMES.join(" ")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(src: &mut dyn ArrivalSource) -> Vec<KernelInstance> {
        let mut out = Vec::new();
        while let Some(t) = src.peek_time() {
            let k = src.next_arrival().expect("peeked arrival vanished");
            assert_eq!(k.arrival_time, t, "peek/pop disagree");
            out.push(k);
        }
        out
    }

    #[test]
    fn poisson_source_matches_frozen_stream() {
        for (mix, per_app, lambda, seed) in
            [(Mix::MIX, 40, 120.0, 7u64), (Mix::ALL, 15, 55.0, 42), (Mix::CI, 1, 9.0, 3)]
        {
            let frozen = Stream::poisson(mix, per_app, lambda, seed);
            let mut src = PoissonSource::new(mix, per_app, lambda, seed);
            let streamed = drain(&mut src);
            assert_eq!(streamed.len(), frozen.len());
            for (a, b) in streamed.iter().zip(&frozen.instances) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits());
                assert_eq!(a.spec.name, b.spec.name);
                assert_eq!(a.spec.grid_blocks, b.spec.grid_blocks);
            }
        }
    }

    #[test]
    fn replay_source_yields_stream_in_order() {
        let stream = Stream::poisson(Mix::MI, 10, 80.0, 5);
        let mut src = ReplaySource::from_stream(&stream);
        let out = drain(&mut src);
        assert_eq!(out.len(), stream.len());
        assert!(src.next_arrival().is_none());
        for (a, b) in out.iter().zip(&stream.instances) {
            assert_eq!(a.id, b.id);
        }
    }

    #[test]
    fn bursty_emits_total_monotone_arrivals() {
        let mut src = BurstySource::new(Mix::MIX, 300, [50.0, 400.0], [0.2, 0.05], 11);
        let out = drain(&mut src);
        assert_eq!(out.len(), 300);
        for w in out.windows(2) {
            assert!(w[0].arrival_time <= w[1].arrival_time);
        }
        let ids: std::collections::HashSet<u64> = out.iter().map(|k| k.id).collect();
        assert_eq!(ids.len(), 300);
        // Determinism given the seed.
        let mut again = BurstySource::new(Mix::MIX, 300, [50.0, 400.0], [0.2, 0.05], 11);
        let out2 = drain(&mut again);
        assert_eq!(out[299].arrival_time, out2[299].arrival_time);
    }

    #[test]
    fn bursty_long_run_rate_near_mean() {
        // Equal sojourns at rates (0.5λ, 1.5λ) must average λ.
        let lambda = 200.0;
        let n = 4000;
        let mut src =
            BurstySource::new(Mix::ALL, n, [0.5 * lambda, 1.5 * lambda], [0.1, 0.1], 17);
        let out = drain(&mut src);
        let span = out.last().unwrap().arrival_time;
        let rate = n as f64 / span;
        assert!((rate / lambda - 1.0).abs() < 0.15, "rate={rate}");
    }

    #[test]
    fn diurnal_rate_tracks_the_curve() {
        let base = 100.0;
        let period = 10.0;
        let mut src = DiurnalSource::new(Mix::MIX, 3000, base, 0.8, period, 23);
        let out = drain(&mut src);
        assert_eq!(out.len(), 3000);
        for w in out.windows(2) {
            assert!(w[0].arrival_time <= w[1].arrival_time);
        }
        // Peak-phase quarters of the cycle must out-arrive trough
        // phases by a wide margin (amp = 0.8 → 9:1 instantaneous).
        let phase = |t: f64| (t / period).fract();
        let peak = out.iter().filter(|k| (0.0..0.5).contains(&phase(k.arrival_time))).count();
        let trough = out.len() - peak;
        assert!(peak > trough * 2, "peak={peak} trough={trough}");
    }

    #[test]
    fn flashcrowd_surge_compresses_the_window() {
        // The scenario surges the middle fifth of the expected span at
        // 3× — that window must hold far more than a fifth of all
        // arrivals (expected share 3·0.2/(0.8 + 3·0.2) ≈ 0.43).
        let mut src =
            scenario_source("flashcrowd", Mix::MIX, 50, 400.0, 11, QosMix::ALL_BATCH).unwrap();
        assert_eq!(src.scenario(), "flashcrowd");
        let out = drain(src.as_mut());
        assert_eq!(out.len(), 200);
        let span = 200.0 / 400.0;
        let (w0, w1) = (0.4 * span, 0.6 * span);
        let in_window =
            out.iter().filter(|k| k.arrival_time >= w0 && k.arrival_time < w1).count();
        assert!(
            in_window * 10 > out.len() * 3,
            "surge window holds {in_window}/{} arrivals",
            out.len()
        );
        // Surge-free construction is untouched: plain diurnal still
        // reports its own scenario and the same seed still replays.
        let a = drain(&mut DiurnalSource::new(Mix::MIX, 100, 400.0, 0.8, 0.1, 11));
        let b = drain(&mut DiurnalSource::new(Mix::MIX, 100, 400.0, 0.8, 0.1, 11));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_time.to_bits(), y.arrival_time.to_bits());
        }
    }

    #[test]
    fn heavytail_buckets_decay() {
        let mut src = HeavyTailSource::new(Mix::MIX, 2000, 100.0, 1.1, 31);
        let out = drain(&mut src);
        assert_eq!(out.len(), 2000);
        let base: usize = out.iter().filter(|k| !k.spec.name.contains('x')).count();
        let elephants: usize = out.iter().filter(|k| k.spec.name.ends_with("x8")).count();
        assert!(base > out.len() / 3, "base={base}");
        assert!(elephants > 0, "no elephants drawn");
        assert!(elephants < base, "tail heavier than body");
        // Scaled variants really carry scaled grids.
        let sample = out.iter().find(|k| k.spec.name.ends_with("x8")).unwrap();
        let orig =
            Mix::MIX.apps().iter().map(|a| a.spec()).find(|s| sample.spec.name.starts_with(s.name) && sample.spec.threads_per_block == s.threads_per_block).unwrap();
        assert_eq!(sample.spec.grid_blocks, orig.grid_blocks * 8);
    }

    #[test]
    fn closed_loop_waits_for_completions() {
        let mut src = ClosedLoopSource::new(Mix::MIX, 2, 10.0, 6, 41);
        // Two clients submit immediately...
        let a = src.next_arrival().unwrap();
        let b = src.next_arrival().unwrap();
        // ...then the fleet is blocked until something completes.
        assert!(src.peek_time().is_none());
        assert!(src.more_expected());
        src.on_completion(a.id, a.arrival_time + 1.0);
        let t3 = src.peek_time().expect("completion must schedule a resubmit");
        assert!(t3 > a.arrival_time + 1.0);
        src.on_completion(b.id, b.arrival_time + 2.0);
        // Drain the remaining 4 jobs by completing everything instantly.
        let mut done = 2;
        while let Some(k) = src.next_arrival() {
            done += 1;
            src.on_completion(k.id, k.arrival_time + 0.5);
        }
        assert_eq!(done, 6);
        assert!(!src.more_expected());
    }

    #[test]
    fn closed_loop_retries_shed_submissions() {
        let mut src = ClosedLoopSource::new(Mix::MIX, 1, 10.0, 3, 41);
        // The lone client submits; the gate sheds it.
        let a = src.next_arrival().unwrap();
        assert_eq!(src.retries(), 0);
        src.on_shed(a.id, a.arrival_time + 0.1);
        // The client is NOT lost: it re-enters think state and will
        // resubmit (the pre-fix behavior dropped it permanently).
        assert_eq!(src.retries(), 1);
        assert!(src.more_expected());
        let b = src.next_arrival().expect("shed client must resubmit");
        assert!(b.id > a.id, "retry must use a fresh id");
        assert!(b.arrival_time > a.arrival_time, "retry waits out a think");
        // A completion resets the strike count; the run still issues
        // its full budget of 3 completed jobs.
        src.on_completion(b.id, b.arrival_time + 0.2);
        let mut done = 1;
        while let Some(k) = src.next_arrival() {
            done += 1;
            src.on_completion(k.id, k.arrival_time + 0.2);
        }
        assert_eq!(done, 3);
        assert!(!src.more_expected());
    }

    #[test]
    fn closed_loop_client_gives_up_after_capped_retries() {
        let mut src = ClosedLoopSource::new(Mix::MIX, 1, 10.0, 5, 43);
        // Shed everything: the client retries MAX_SHED_RETRIES times,
        // then abandons the submission for good.
        let mut sheds = 0;
        while let Some(k) = src.next_arrival() {
            sheds += 1;
            src.on_shed(k.id, k.arrival_time + 0.01);
        }
        assert_eq!(sheds, 1 + MAX_SHED_RETRIES as u64);
        assert_eq!(src.retries(), MAX_SHED_RETRIES as u64);
        // No live client remains, so the source reports exhaustion even
        // though the job budget was never filled.
        assert!(!src.more_expected());
        assert!(src.peek_time().is_none());
    }

    #[test]
    fn open_loop_sources_ignore_shed_feedback() {
        let mut src = PoissonSource::new(Mix::MIX, 4, 100.0, 9);
        let a = src.next_arrival().unwrap();
        src.on_shed(a.id, a.arrival_time);
        assert_eq!(src.retries(), 0);
        let rest = drain(&mut src);
        assert_eq!(rest.len(), 15, "shed feedback must not perturb open loops");
    }

    #[test]
    fn trace_parses_sorts_and_overrides_grid() {
        let json = r#"
            [
              {"app": "MM", "t": 2.0},
              {"app": "PC", "t": 0.5, "grid": 512},
              {"app": "tea", "t": 1.25e0}
            ]
        "#;
        let out = parse_trace(json).unwrap();
        assert_eq!(out.len(), 3);
        // Sorted by time; ids keep file order.
        assert_eq!(out[0].spec.name, "PC");
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].spec.grid_blocks, 512);
        assert_eq!(out[1].spec.name, "TEA");
        assert_eq!(out[2].spec.name, "MM");
        assert_eq!(out[2].arrival_time, 2.0);
        // Empty trace is fine.
        assert!(parse_trace("[]").unwrap().is_empty());
    }

    #[test]
    fn trace_rejects_malformed_input() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("[{\"app\": \"MM\"}]").is_err()); // missing t
        assert!(parse_trace("[{\"app\": \"NOPE\", \"t\": 1}]").is_err());
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": -1.0}]").is_err());
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": 1, \"grid\": 0}]").is_err());
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": 1}] junk").is_err());
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": 1, \"bogus\": 2}]").is_err());
    }

    #[test]
    fn scenario_factory_covers_all_names() {
        for name in SCENARIO_NAMES {
            let src = scenario_source(name, Mix::MIX, 3, 50.0, 9, QosMix::ALL_BATCH).unwrap();
            assert!(!src.scenario().is_empty());
        }
        assert!(scenario_source("nope", Mix::MIX, 3, 50.0, 9, QosMix::ALL_BATCH).is_err());
        assert!(scenario_source("poisson", Mix::MIX, 3, 0.0, 9, QosMix::ALL_BATCH).is_err());
    }

    #[test]
    fn qos_mix_stamps_without_perturbing_arrivals() {
        // Same seed with and without a latency share: identical arrival
        // sequences (ids, bit-exact times, specs) — only the Qos labels
        // differ, and they hit the requested fraction.
        let mix = QosMix::latency_share(0.5, 2.0);
        for name in SCENARIO_NAMES {
            if name == "closed" {
                continue; // completion-driven; drained below without an engine
            }
            let mut plain = scenario_source(name, Mix::MIX, 4, 80.0, 77, QosMix::ALL_BATCH)
                .unwrap();
            let mut stamped = scenario_source(name, Mix::MIX, 4, 80.0, 77, mix).unwrap();
            let a = drain(plain.as_mut());
            let b = drain(stamped.as_mut());
            assert_eq!(a.len(), b.len(), "{name}");
            let mut latency = 0;
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "{name}");
                assert_eq!(x.arrival_time.to_bits(), y.arrival_time.to_bits(), "{name}");
                assert_eq!(x.spec.name, y.spec.name, "{name}");
                assert_eq!(x.qos, Qos::BATCH, "{name}: un-stamped arrival not batch");
                if y.qos.is_latency() {
                    latency += 1;
                    assert_eq!(y.qos.deadline, Some(y.arrival_time + 2.0), "{name}");
                } else {
                    assert_eq!(y.qos.deadline, None, "{name}");
                }
            }
            assert_eq!(latency, a.len() / 2, "{name}: latency share off");
        }
    }

    #[test]
    fn closed_loop_stamps_qos_too() {
        let mut src =
            ClosedLoopSource::new(Mix::MIX, 2, 10.0, 8, 41).with_qos(QosMix::latency_share(1.0, 0.5));
        let mut seen = 0;
        while let Some(k) = src.next_arrival() {
            assert!(k.qos.is_latency());
            assert_eq!(k.qos.deadline, Some(k.arrival_time + 0.5));
            seen += 1;
            src.on_completion(k.id, k.arrival_time + 0.1);
        }
        assert_eq!(seen, 8);
    }

    #[test]
    fn trace_round_trips_qos_fields() {
        let json = r#"
            [
              {"app": "MM", "t": 0.0},
              {"app": "PC", "t": 0.5, "grid": 512, "class": "latency", "deadline": 2.5},
              {"app": "TEA", "t": 1.0, "class": "batch", "deadline": 9.0}
            ]
        "#;
        let out = parse_trace(json).unwrap();
        assert_eq!(out[0].qos, Qos::BATCH);
        assert!(out[1].qos.is_latency());
        assert_eq!(out[1].qos.deadline, Some(2.5));
        assert_eq!(out[2].qos.class, ServiceClass::Batch);
        assert_eq!(out[2].qos.deadline, Some(9.0));
        // write → parse is the identity on times, specs and QoS.
        let written = write_trace(&out).unwrap();
        let back = parse_trace(&written).unwrap();
        assert_eq!(back.len(), out.len());
        for (a, b) in back.iter().zip(&out) {
            assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits());
            assert_eq!(a.spec.name, b.spec.name);
            assert_eq!(a.spec.grid_blocks, b.spec.grid_blocks);
            assert_eq!(a.qos, b.qos);
        }
    }

    #[test]
    fn write_trace_maps_heavytail_variants_to_base_apps() {
        let mut src = HeavyTailSource::new(Mix::MIX, 400, 100.0, 1.1, 31)
            .with_qos(QosMix::latency_share(0.25, 1.0));
        let out = drain(&mut src);
        let written = write_trace(&out).unwrap();
        assert!(!written.contains('x'), "variant names must not leak into traces");
        let back = parse_trace(&written).unwrap();
        assert_eq!(back.len(), out.len());
        // Grids (including scaled elephants) survive the round trip.
        for (a, b) in back.iter().zip(&out) {
            assert_eq!(a.spec.grid_blocks, b.spec.grid_blocks);
            assert_eq!(a.qos, b.qos);
        }
    }

    #[test]
    fn trace_rejects_bad_qos_fields() {
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": 1, \"class\": \"vip\"}]").is_err());
        assert!(parse_trace("[{\"app\": \"MM\", \"t\": 1, \"deadline\": 0.5}]").is_err());
    }

    #[test]
    fn recording_source_tees_arrivals() {
        let stream = Stream::poisson(Mix::MIX, 3, 100.0, 5);
        let mut inner = ReplaySource::from_stream(&stream);
        let mut rec = RecordingSource::new(&mut inner);
        let out = drain(&mut rec);
        let log = rec.into_log();
        assert_eq!(log.len(), out.len());
        for (a, b) in log.iter().zip(&stream.instances) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits());
        }
    }
}
