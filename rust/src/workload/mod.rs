//! Workload generation (paper §5.1, Table 5).
//!
//! Four kernel mixes — CI (compute-intensive), MI (memory-intensive),
//! MIX and ALL — with Poisson arrivals, equal rates per application.
//! The paper initiates 1000 instances of each kernel in the mix and
//! submits them according to the Poisson process, with λ large enough
//! that at least two kernels are always pending.
//!
//! [`Stream`] is the frozen pre-materialized form; the [`arrivals`]
//! module streams workloads into the engine online ([`ArrivalSource`]),
//! including scenarios a sorted `Vec` cannot express (bursty, diurnal,
//! heavy-tailed, closed-loop, trace replay).

pub mod arrivals;
pub mod qos;
pub mod tenancy;

pub use arrivals::{
    parse_trace, scenario_source, trace_source, write_trace, ArrivalSource, BurstySource,
    ClosedLoopSource, DiurnalSource, HeavyTailSource, PoissonSource, RecordingSource,
    ReplaySource, SCENARIO_NAMES,
};
pub use qos::QosMix;
pub use tenancy::TenantMix;

use crate::kernel::{BenchmarkApp, KernelInstance};
use crate::stats::Xoshiro256;

/// The paper's four workload mixes (Table 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mix {
    /// BS, MM, TEA, MRIQ.
    CI,
    /// PC, SPMV, ST, SAD.
    MI,
    /// PC, BS, TEA, SAD.
    MIX,
    /// All eight applications.
    ALL,
}

impl Mix {
    /// The four mixes, in paper order.
    pub const ALL_MIXES: [Mix; 4] = [Mix::CI, Mix::MI, Mix::MIX, Mix::ALL];

    /// Table 5 mix name.
    pub fn name(&self) -> &'static str {
        match self {
            Mix::CI => "CI",
            Mix::MI => "MI",
            Mix::MIX => "MIX",
            Mix::ALL => "ALL",
        }
    }

    /// Case-insensitive lookup by Table 5 mix name.
    pub fn from_name(s: &str) -> Option<Mix> {
        Self::ALL_MIXES.iter().copied().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// Applications in the mix (Table 5).
    pub fn apps(&self) -> Vec<BenchmarkApp> {
        use BenchmarkApp::*;
        match self {
            Mix::CI => vec![BS, MM, TEA, MRIQ],
            Mix::MI => vec![PC, SPMV, ST, SAD],
            Mix::MIX => vec![PC, BS, TEA, SAD],
            Mix::ALL => vec![PC, SPMV, ST, BS, MM, TEA, MRIQ, SAD],
        }
    }
}

/// A generated submission stream: kernel instances sorted by arrival.
#[derive(Debug, Clone)]
pub struct Stream {
    /// Instances sorted by arrival time.
    pub instances: Vec<KernelInstance>,
}

impl Stream {
    /// Generate `per_app` instances of every application in `mix`, with
    /// exponential inter-arrival times of rate `lambda` (arrivals/sec)
    /// per application, merged and sorted.
    pub fn poisson(mix: Mix, per_app: u32, lambda: f64, seed: u64) -> Stream {
        let mut rng = Xoshiro256::new(seed);
        let mut instances = Vec::new();
        let mut id = 0u64;
        for app in mix.apps() {
            let mut t = 0.0f64;
            for _ in 0..per_app {
                t += rng.exponential(lambda);
                instances.push(KernelInstance::new(id, app.spec(), t));
                id += 1;
            }
        }
        instances.sort_by(|a, b| a.arrival_time.total_cmp(&b.arrival_time));
        Stream { instances }
    }

    /// All instances available at time zero (the paper's saturated-queue
    /// assumption: λ high enough that ≥2 kernels are always pending).
    pub fn saturated(mix: Mix, per_app: u32, seed: u64) -> Stream {
        let mut rng = Xoshiro256::new(seed);
        let mut instances = Vec::new();
        let mut id = 0u64;
        for app in mix.apps() {
            for _ in 0..per_app {
                instances.push(KernelInstance::new(id, app.spec(), 0.0));
                id += 1;
            }
        }
        // Shuffle so arrival order interleaves applications.
        rng.shuffle(&mut instances);
        Stream { instances }
    }

    /// Iterate submissions in arrival order (instances are stored
    /// sorted by arrival time). The scheduling engine consumes this to
    /// admit kernels online.
    pub fn arrivals(&self) -> impl Iterator<Item = KernelInstance> + '_ {
        self.instances.iter().cloned()
    }

    /// Number of instances in the stream.
    pub fn len(&self) -> usize {
        self.instances.len()
    }

    /// Whether the stream holds no instances.
    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Total thread blocks across the stream (the work-conservation
    /// invariant the property tests check against schedules).
    pub fn total_blocks(&self) -> u64 {
        self.instances.iter().map(|k| k.spec.grid_blocks as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixes_match_table5() {
        assert_eq!(Mix::CI.apps().len(), 4);
        assert_eq!(Mix::MI.apps().len(), 4);
        assert_eq!(Mix::MIX.apps().len(), 4);
        assert_eq!(Mix::ALL.apps().len(), 8);
        assert!(Mix::CI.apps().contains(&BenchmarkApp::MRIQ));
        assert!(Mix::MI.apps().contains(&BenchmarkApp::PC));
        assert!(Mix::MIX.apps().contains(&BenchmarkApp::TEA));
    }

    #[test]
    fn poisson_stream_sorted_and_complete() {
        let s = Stream::poisson(Mix::MIX, 50, 100.0, 7);
        assert_eq!(s.len(), 200);
        for w in s.instances.windows(2) {
            assert!(w[0].arrival_time <= w[1].arrival_time);
        }
        // Unique ids.
        let mut ids: Vec<_> = s.instances.iter().map(|k| k.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 200);
    }

    #[test]
    fn poisson_interarrival_mean() {
        let s = Stream::poisson(Mix::CI, 2000, 10.0, 11);
        // Per-app rate 10/s, 4 apps -> merged rate 40/s; last arrival
        // around 2000/10 = 200s.
        let last = s.instances.last().unwrap().arrival_time;
        assert!((last - 200.0).abs() < 20.0, "last={last}");
    }

    #[test]
    fn saturated_all_at_zero() {
        let s = Stream::saturated(Mix::ALL, 10, 3);
        assert_eq!(s.len(), 80);
        assert!(s.instances.iter().all(|k| k.arrival_time == 0.0));
    }

    #[test]
    fn arrivals_iterate_in_order() {
        let s = Stream::poisson(Mix::MIX, 10, 80.0, 5);
        let times: Vec<f64> = s.arrivals().map(|k| k.arrival_time).collect();
        assert_eq!(times.len(), s.len());
        for w in times.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Stream::poisson(Mix::MI, 20, 50.0, 42);
        let b = Stream::poisson(Mix::MI, 20, 50.0, 42);
        for (x, y) in a.instances.iter().zip(&b.instances) {
            assert_eq!(x.arrival_time, y.arrival_time);
            assert_eq!(x.spec.name, y.spec.name);
        }
    }
}
