//! QoS mix specs for arrival sources.
//!
//! A [`QosMix`] tells a source what fraction of its arrivals are
//! latency-class and how their deadlines are stamped. Class assignment
//! is **deterministic in the arrival index** (no RNG is consumed), so
//! installing a mix on a source never perturbs its arrival-time draw
//! sequence: with [`QosMix::ALL_BATCH`] every source stays bit-identical
//! to its un-annotated form — the QoS-off differential the invariants
//! suite pins.

use crate::kernel::{Qos, ServiceClass};

/// The QoS mix a source stamps onto its arrivals.
///
/// # Examples
///
/// A quarter of arrivals latency-class, deadlined 2 s after arrival:
///
/// ```
/// use kernelet::workload::QosMix;
///
/// let mix = QosMix::latency_share(0.25, 2.0);
/// let q = mix.stamp(3, 10.0); // arrival id 3 at t = 10 s
/// assert!(q.is_latency());
/// assert_eq!(q.deadline, Some(12.0));
/// // Stamping is deterministic and hits the fraction exactly:
/// let latency = (0..100).filter(|&id| mix.stamp(id, 0.0).is_latency()).count();
/// assert_eq!(latency, 25);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosMix {
    /// Fraction of arrivals stamped latency-class, in `[0, 1]`.
    pub latency_fraction: f64,
    /// Relative deadline (seconds after arrival) stamped on
    /// latency-class arrivals; `None` leaves them best-effort.
    pub latency_deadline_secs: Option<f64>,
    /// Relative deadline for batch arrivals (usually `None`).
    pub batch_deadline_secs: Option<f64>,
}

impl Default for QosMix {
    fn default() -> Self {
        Self::ALL_BATCH
    }
}

impl QosMix {
    /// The QoS-agnostic mix: everything batch, nothing deadlined.
    pub const ALL_BATCH: QosMix = QosMix {
        latency_fraction: 0.0,
        latency_deadline_secs: None,
        batch_deadline_secs: None,
    };

    /// A two-class mix: `fraction` of arrivals are latency-class with a
    /// relative deadline of `deadline_secs`; the rest are best-effort
    /// batch.
    pub fn latency_share(fraction: f64, deadline_secs: f64) -> QosMix {
        assert!((0.0..=1.0).contains(&fraction), "latency fraction {fraction} out of [0,1]");
        assert!(
            deadline_secs.is_finite() && deadline_secs > 0.0,
            "relative deadline {deadline_secs} must be positive"
        );
        QosMix {
            latency_fraction: fraction,
            latency_deadline_secs: Some(deadline_secs),
            batch_deadline_secs: None,
        }
    }

    /// Whether this mix stamps anything other than the default
    /// annotation.
    pub fn is_all_batch(&self) -> bool {
        self.latency_fraction == 0.0 && self.batch_deadline_secs.is_none()
    }

    /// Class/deadline for arrival `id` at `arrival_secs`.
    ///
    /// Arrival `id` is latency-class iff the integer part of
    /// `latency_fraction × id` advances at `id + 1` — an evenly spaced
    /// interleave with exactly `⌊n·fraction⌋` latency arrivals in every
    /// prefix of `n`. Deterministic and RNG-free by design: sources call
    /// this at emission time without touching their generators.
    pub fn stamp(&self, id: u64, arrival_secs: f64) -> Qos {
        let is_latency = self.latency_fraction > 0.0 && {
            let lo = (self.latency_fraction * id as f64).floor();
            let hi = (self.latency_fraction * (id + 1) as f64).floor();
            hi > lo
        };
        if is_latency {
            Qos {
                class: ServiceClass::Latency,
                deadline: self.latency_deadline_secs.map(|d| arrival_secs + d),
            }
        } else {
            Qos {
                class: ServiceClass::Batch,
                deadline: self.batch_deadline_secs.map(|d| arrival_secs + d),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_batch_stamps_the_default() {
        let mix = QosMix::ALL_BATCH;
        assert!(mix.is_all_batch());
        for id in 0..100 {
            assert_eq!(mix.stamp(id, id as f64), Qos::BATCH);
        }
    }

    #[test]
    fn latency_share_hits_the_fraction_exactly() {
        for (frac, n) in [(0.3, 1000u64), (0.5, 101), (1.0, 64), (0.25, 7)] {
            let mix = QosMix::latency_share(frac, 1.0);
            let latency =
                (0..n).filter(|&id| mix.stamp(id, 0.0).is_latency()).count() as u64;
            assert_eq!(latency, (frac * n as f64).floor() as u64, "frac={frac} n={n}");
        }
    }

    #[test]
    fn latency_arrivals_are_evenly_interleaved() {
        let mix = QosMix::latency_share(0.5, 2.0);
        let classes: Vec<bool> = (0..10).map(|id| mix.stamp(id, 0.0).is_latency()).collect();
        // Every other arrival, not a front-loaded block.
        assert_eq!(classes, [false, true, false, true, false, true, false, true, false, true]);
    }

    #[test]
    fn deadlines_are_relative_to_arrival() {
        let mix = QosMix::latency_share(1.0, 3.0);
        let q = mix.stamp(4, 10.0);
        assert!(q.is_latency());
        assert_eq!(q.deadline, Some(13.0));
        // Batch arrivals of a latency mix stay best-effort.
        let half = QosMix::latency_share(0.5, 3.0);
        assert_eq!(half.stamp(0, 10.0).deadline, None);
    }

    #[test]
    #[should_panic]
    fn out_of_range_fraction_rejected() {
        let _ = QosMix::latency_share(1.5, 1.0);
    }
}
