//! Tenant mix specs for arrival sources.
//!
//! A [`TenantMix`] tells a workload how its arrivals are split across
//! tenants (users, job queues, customers). Like [`QosMix`](crate::workload::QosMix),
//! assignment is **deterministic in the emission index** and consumes
//! **no RNG**, so attaching a mix to a source never perturbs its
//! arrival-time draw sequence — and the single-tenant mix
//! ([`TenantMix::SINGLE`]) attaches as the identity transform (the
//! inner source is returned unwrapped), so tenancy-off runs stay
//! bit-identical to the pre-tenant engine. The invariants suite pins
//! this differentially on every scenario.

use crate::kernel::{KernelInstance, TenantId};
use crate::workload::ArrivalSource;

/// The tenant split a workload stamps onto its arrivals.
///
/// Holds one *arrival share* per tenant (normalized to sum 1). Shares
/// describe who submits how much; they are independent of the fairness
/// *weights* a selector enforces — a flooding tenant has a large share
/// and an ordinary weight.
///
/// # Examples
///
/// ```
/// use kernelet::kernel::TenantId;
/// use kernelet::workload::TenantMix;
///
/// let mix = TenantMix::split(&[3.0, 1.0]); // tenant 0 submits 3x tenant 1
/// let counts = (0..100).fold([0u64; 2], |mut c, i| {
///     c[mix.stamp(i).0 as usize] += 1;
///     c
/// });
/// assert_eq!(counts, [75, 25]);
/// assert!(TenantMix::SINGLE.is_single());
/// ```
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TenantMix {
    /// Normalized arrival share per tenant; empty means single-tenant
    /// (everything stays [`TenantId::SOLE`]).
    shares: Vec<f64>,
}

impl TenantMix {
    /// The tenancy-agnostic mix: one anonymous tenant, no stamping.
    /// Attaching it to a source is the identity transform.
    pub const SINGLE: TenantMix = TenantMix { shares: Vec::new() };

    /// A multi-tenant split with the given relative arrival shares
    /// (normalized internally). A split with zero or one entry is the
    /// single-tenant mix.
    pub fn split(shares: &[f64]) -> TenantMix {
        if shares.len() <= 1 {
            return TenantMix::SINGLE;
        }
        let total: f64 = shares.iter().sum();
        assert!(
            shares.iter().all(|&s| s.is_finite() && s > 0.0) && total > 0.0,
            "tenant shares must be positive and finite: {shares:?}"
        );
        TenantMix { shares: shares.iter().map(|s| s / total).collect() }
    }

    /// Whether this mix stamps anything other than [`TenantId::SOLE`].
    pub fn is_single(&self) -> bool {
        self.shares.len() <= 1
    }

    /// Number of tenants (1 for the single-tenant mix).
    pub fn tenants(&self) -> usize {
        self.shares.len().max(1)
    }

    /// Normalized arrival share of `tenant` (1.0 under the
    /// single-tenant mix).
    pub fn share(&self, tenant: TenantId) -> f64 {
        if self.is_single() {
            1.0
        } else {
            self.shares.get(tenant.0 as usize).copied().unwrap_or(0.0)
        }
    }

    /// Tenant of the `index`-th emitted arrival.
    ///
    /// The arrival goes to the first tenant whose *cumulative* share
    /// floor advances at `index + 1` — the same integer-part rule
    /// [`QosMix::stamp`](crate::workload::QosMix::stamp) uses, applied
    /// to the cumulative share vector. For two tenants the split is
    /// exact (`⌊n·share⌋` arrivals in every prefix of `n`); for more,
    /// counts track their shares within a small bounded drift (an exact
    /// simultaneous floor partition does not exist for ≥3 shares).
    /// Deterministic and RNG-free by design.
    pub fn stamp(&self, index: u64) -> TenantId {
        if self.is_single() {
            return TenantId::SOLE;
        }
        let mut cumulative = 0.0;
        for (j, share) in self.shares.iter().enumerate() {
            cumulative += share;
            let lo = (cumulative * index as f64).floor();
            let hi = (cumulative * (index + 1) as f64).floor();
            if hi > lo {
                return TenantId(j as u32);
            }
        }
        // Float round-off can leave the last cumulative share a hair
        // under 1.0; the tail tenant absorbs those indexes.
        TenantId(self.shares.len() as u32 - 1)
    }

    /// Wrap `src` so every emitted arrival is stamped with its tenant.
    ///
    /// The single-tenant mix returns `src` unchanged — structurally the
    /// identity, so a tenancy-off pipeline is the exact pre-tenant
    /// object graph, not merely an equivalent one.
    pub fn attach(&self, src: Box<dyn ArrivalSource>) -> Box<dyn ArrivalSource> {
        if self.is_single() {
            src
        } else {
            Box::new(TenantStamped { mix: self.clone(), inner: src, emitted: 0 })
        }
    }
}

/// An [`ArrivalSource`] adapter stamping tenants by emission index;
/// every other trait method delegates to the inner source untouched.
struct TenantStamped {
    mix: TenantMix,
    inner: Box<dyn ArrivalSource>,
    emitted: u64,
}

impl ArrivalSource for TenantStamped {
    fn scenario(&self) -> &'static str {
        self.inner.scenario()
    }

    fn peek_time(&self) -> Option<f64> {
        self.inner.peek_time()
    }

    fn next_arrival(&mut self) -> Option<KernelInstance> {
        let k = self.inner.next_arrival()?;
        let tenant = self.mix.stamp(self.emitted);
        self.emitted += 1;
        Some(k.with_tenant(tenant))
    }

    fn on_completion(&mut self, id: u64, t_secs: f64) {
        self.inner.on_completion(id, t_secs);
    }

    fn on_shed(&mut self, id: u64, t_secs: f64) {
        self.inner.on_shed(id, t_secs);
    }

    fn retries(&self) -> u64 {
        self.inner.retries()
    }

    fn more_expected(&self) -> bool {
        self.inner.more_expected()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{scenario_source, Mix, QosMix};

    #[test]
    fn single_mix_stamps_sole_tenant() {
        for mix in [TenantMix::SINGLE, TenantMix::split(&[1.0]), TenantMix::split(&[])] {
            assert!(mix.is_single());
            assert_eq!(mix.tenants(), 1);
            assert_eq!(mix.share(TenantId::SOLE), 1.0);
            for i in 0..50 {
                assert_eq!(mix.stamp(i), TenantId::SOLE);
            }
        }
    }

    #[test]
    fn two_way_split_is_exact_in_every_prefix() {
        for (a, b) in [(1.0, 1.0), (10.0, 1.0), (1.0, 3.0)] {
            let mix = TenantMix::split(&[a, b]);
            let share0 = a / (a + b);
            let mut count0 = 0u64;
            for n in 0..500u64 {
                if mix.stamp(n) == TenantId(0) {
                    count0 += 1;
                }
                let expect = (share0 * (n + 1) as f64).floor() as u64;
                assert_eq!(count0, expect, "share {share0} prefix {}", n + 1);
            }
        }
    }

    #[test]
    fn multi_way_split_tracks_shares() {
        let shares = [5.0, 3.0, 2.0];
        let mix = TenantMix::split(&shares);
        let n = 1000u64;
        let mut counts = [0u64; 3];
        for i in 0..n {
            counts[mix.stamp(i).0 as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<u64>(), n);
        for (j, &c) in counts.iter().enumerate() {
            let expect = shares[j] / 10.0 * n as f64;
            assert!(
                (c as f64 - expect).abs() <= 0.02 * n as f64,
                "tenant {j}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn attach_single_is_structural_identity() {
        // Same seed, with and without the single-tenant attach: every
        // emitted instance is bit-identical, including tenant ids.
        let mk = || scenario_source("poisson", Mix::MIX, 3, 200.0, 11, QosMix::ALL_BATCH).unwrap();
        let mut plain = mk();
        let mut attached = TenantMix::SINGLE.attach(mk());
        while let Some(a) = plain.next_arrival() {
            let b = attached.next_arrival().expect("attached source ended early");
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits());
            assert_eq!(a.tenant, b.tenant);
        }
        assert!(attached.next_arrival().is_none());
    }

    #[test]
    fn attach_stamps_without_perturbing_arrivals() {
        let mk = || scenario_source("bursty", Mix::MIX, 4, 300.0, 13, QosMix::ALL_BATCH).unwrap();
        let mut plain = mk();
        let mix = TenantMix::split(&[10.0, 1.0]);
        let mut stamped = mix.attach(mk());
        let mut seen = [false; 2];
        while let Some(a) = plain.next_arrival() {
            let b = stamped.next_arrival().expect("stamped source ended early");
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits());
            assert_eq!(a.spec, b.spec);
            seen[b.tenant.0 as usize] = true;
        }
        assert!(seen[0] && seen[1], "both tenants must appear in the stream");
    }

    #[test]
    #[should_panic]
    fn non_positive_share_rejected() {
        let _ = TenantMix::split(&[1.0, 0.0]);
    }
}
