//! Admission-control invariants: the differential pin (an `AdmitAll`
//! gate is bit-identical to the pre-admission engine on every
//! scenario), the SLO-guard property (under bursty overload it sheds
//! only batch kernels and strictly improves latency-class p99 and
//! misses over the open door), and the accounting partition
//! (completed + shed + deferred-unfinished + incomplete == arrivals,
//! per class, always).

use kernelet::config::GpuConfig;
use kernelet::coordinator::{
    AdmissionDecision, AdmissionSpec, Coordinator, Engine, EngineBuilder, KerneletSelector,
};
use kernelet::figures::throughput::base_capacity_kps;
use kernelet::kernel::{BenchmarkApp, KernelInstance};
use kernelet::workload::{scenario_source, ArrivalSource, Mix, QosMix, ReplaySource, SCENARIO_NAMES};

const SEED: u64 = 0xAD_0415;

fn drain_source(src: &mut dyn ArrivalSource) -> Vec<KernelInstance> {
    let mut out = Vec::new();
    while src.peek_time().is_some() {
        out.push(src.next_arrival().expect("peeked arrival vanished"));
    }
    out
}

/// DIFFERENTIAL: with the `AdmitAll` policy installed, every scenario
/// schedules bit-identically to the pre-admission engine — same
/// completion map, slice trace, queue-depth timeline, round/solo
/// counts — and the admission report degenerates to all-admitted.
#[test]
fn admit_all_is_bit_identical_to_unguarded_engine() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    for scenario in SCENARIO_NAMES {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 4, 2.0 * capacity, SEED, qos)
                .expect("valid scenario")
        };
        let plain = Engine::new(&coord).run_source(&mut KerneletSelector, mk().as_mut());
        let gated = EngineBuilder::new(&coord)
            .admission(AdmissionSpec::AdmitAll.build())
            .build()
            .run_source(&mut KerneletSelector, mk().as_mut());
        assert_eq!(gated.total_cycles, plain.total_cycles, "{scenario}: total_cycles");
        assert_eq!(gated.completion, plain.completion, "{scenario}: completion map");
        assert_eq!(gated.slice_trace, plain.slice_trace, "{scenario}: slice trace");
        assert_eq!(gated.queue_depth, plain.queue_depth, "{scenario}: queue depth");
        assert_eq!(gated.coschedule_rounds, plain.coschedule_rounds, "{scenario}: rounds");
        assert_eq!(gated.solo_slices, plain.solo_slices, "{scenario}: solo slices");
        assert_eq!(gated.qos, plain.qos, "{scenario}: per-class stats");
        // Open door: everything offered was admitted, nothing else.
        let a = &gated.admission;
        assert_eq!(a.policy, "admitall", "{scenario}");
        assert_eq!(a.total_shed(), 0, "{scenario}");
        assert_eq!(a.total_deferred_unfinished(), 0, "{scenario}");
        assert_eq!(a.total_arrivals(), gated.kernels_completed + gated.incomplete, "{scenario}");
        // The ungated engine reports the same partition under "none".
        assert_eq!(plain.admission.policy, "none", "{scenario}");
        assert_eq!(a.latency.arrivals, plain.admission.latency.arrivals, "{scenario}");
        assert_eq!(a.batch.arrivals, plain.admission.batch.arrivals, "{scenario}");
    }
}

/// PROPERTY (the tentpole acceptance): under bursty overload with a
/// latency/batch mix and a class-blind scheduler, the SLO guard sheds
/// or defers only batch-class kernels and strictly improves the
/// latency class's p99 turnaround *and* deadline-miss count over the
/// open door.
#[test]
fn slo_guard_sheds_only_batch_and_beats_admit_all_under_bursty_overload() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let deadline_scale = 4.0;
    let qos = QosMix::latency_share(0.25, deadline_scale / capacity);
    let offered = 3.0 * capacity; // sustained 3x over-subscription
    let mk = || {
        scenario_source("bursty", Mix::MIX, 30, offered, SEED, qos).expect("valid scenario")
    };

    let open = Engine::new(&coord).run_source(&mut KerneletSelector, mk().as_mut());
    let spec = AdmissionSpec::for_policy("sloguard", capacity, deadline_scale, 16);
    let guarded = EngineBuilder::new(&coord)
        .admission(spec.build())
        .build()
        .run_source(&mut KerneletSelector, mk().as_mut());

    // Craft check: the open door really is overloaded — a class-blind
    // queue at 3x load makes late latency kernels wait out the whole
    // backlog, far past deadlines at 4x the mean service time.
    assert!(
        open.qos.latency.deadline_misses > 0,
        "craft broken: open door missed nothing at 3x bursty overload"
    );

    // The guard never touches the class it protects...
    let a = &guarded.admission;
    assert_eq!(a.latency.shed, 0, "sloguard shed a latency kernel");
    assert_eq!(a.latency.deferrals, 0, "sloguard deferred a latency kernel");
    assert_eq!(a.latency.admitted, a.latency.arrivals);
    // ...and under this pressure it must actually push back on batch.
    assert!(
        a.batch.shed + a.batch.deferrals > 0,
        "sloguard never engaged under 3x overload: {a:?}"
    );

    // Strictly better latency-class tail and misses.
    assert!(
        guarded.qos.latency.p99_turnaround_secs < open.qos.latency.p99_turnaround_secs,
        "guarded p99 {} >= open p99 {}",
        guarded.qos.latency.p99_turnaround_secs,
        open.qos.latency.p99_turnaround_secs
    );
    assert!(
        guarded.qos.latency.deadline_misses < open.qos.latency.deadline_misses,
        "guarded misses {} >= open misses {}",
        guarded.qos.latency.deadline_misses,
        open.qos.latency.deadline_misses
    );
}

/// PROPERTY: shed + deferred-unfinished + completed + incomplete
/// exactly partitions the arrivals, per class, for every policy on
/// open- and closed-loop scenarios alike (for open-loop scenarios the
/// arrival counts are cross-checked against an engine-free twin drain
/// of the same source).
#[test]
fn admission_counts_partition_arrivals_exactly() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.25, 4.0 / capacity);
    let specs = [
        AdmissionSpec::BacklogCap { cap: 4 },
        AdmissionSpec::for_policy("sloguard", capacity, 4.0, 8),
    ];
    for scenario in ["poisson", "bursty", "heavytail", "closed"] {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 6, 2.5 * capacity, SEED ^ 7, qos)
                .expect("valid scenario")
        };
        for spec in specs {
            let rep = EngineBuilder::new(&coord)
                .admission(spec.build())
                .build()
                .run_source(&mut KerneletSelector, mk().as_mut());
            let a = &rep.admission;
            for (class, stats, adm) in [
                ("latency", &rep.qos.latency, &a.latency),
                ("batch", &rep.qos.batch, &a.batch),
            ] {
                assert_eq!(
                    adm.admitted + adm.shed + adm.deferred_unfinished,
                    adm.arrivals,
                    "{scenario}/{}/{class}: gate accounting",
                    a.policy
                );
                let incomplete = adm.admitted - stats.completed;
                assert_eq!(
                    stats.completed + adm.shed + adm.deferred_unfinished + incomplete,
                    adm.arrivals,
                    "{scenario}/{}/{class}: partition",
                    a.policy
                );
            }
            // The engine drains everything it admits.
            assert_eq!(rep.incomplete, 0, "{scenario}/{}", a.policy);
            assert_eq!(
                rep.kernels_completed + a.total_shed() + a.total_deferred_unfinished(),
                a.total_arrivals(),
                "{scenario}/{}",
                a.policy
            );
            // Open-loop scenarios: the gate saw exactly the arrivals
            // the source generates (closed loops are completion-driven,
            // so shedding legitimately changes the arrival count).
            if scenario != "closed" {
                let twin = drain_source(mk().as_mut());
                assert_eq!(a.total_arrivals(), twin.len(), "{scenario}/{}", a.policy);
                let latency = twin.iter().filter(|k| k.qos.is_latency()).count();
                assert_eq!(a.latency.arrivals, latency, "{scenario}/{}", a.policy);
            }
        }
    }
}

/// PROPERTY: a backlog cap really bounds the pending set — the queue
/// depth sampled at every dispatch decision never exceeds the cap.
#[test]
fn backlog_cap_bounds_queue_depth() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let cap = 5usize;
    let mut source = scenario_source(
        "bursty",
        Mix::MIX,
        20,
        4.0 * capacity,
        SEED ^ 99,
        QosMix::ALL_BATCH,
    )
    .unwrap();
    let rep = EngineBuilder::new(&coord)
        .admission(AdmissionSpec::BacklogCap { cap }.build())
        .build()
        .run_source(&mut KerneletSelector, source.as_mut());
    assert!(
        rep.peak_queue_depth() <= cap,
        "peak {} exceeds cap {cap}",
        rep.peak_queue_depth()
    );
    // 4x overload against a cap of 5 must shed...
    assert!(rep.admission.total_shed() > 0);
    // ...and what it sheds it never runs.
    assert_eq!(
        rep.kernels_completed + rep.admission.total_shed(),
        rep.admission.total_arrivals()
    );
}

/// Deferred kernels re-enter when pressure drops: a crafted run where
/// every batch kernel is deferred behind a head-of-queue kernel, then
/// released and completed once it drains — nothing shed, nothing left
/// deferred.
#[test]
fn deferred_kernels_are_released_and_complete() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let pc = BenchmarkApp::PC.spec();
    let mm = BenchmarkApp::MM.spec();
    let est_pc = coord.gpu.cycles_to_secs(coord.simcache.solo_full(&pc));
    // Budget below the head kernel's service estimate: every batch
    // arrival behind it is deferred until it drains.
    let spec = AdmissionSpec::SloGuard { slack_budget_secs: 0.5 * est_pc, max_deferred: 16 };
    let instances = vec![
        KernelInstance::new(0, pc, 0.0),
        KernelInstance::new(1, mm.clone(), 0.0),
        KernelInstance::new(2, mm.clone(), 0.0),
        KernelInstance::new(3, mm, 0.0),
    ];
    let mut engine = EngineBuilder::new(&coord).admission(spec.build()).build();
    // The head is admitted; the rest defer at the gate.
    for k in instances {
        let d = engine.offer(k.clone());
        if k.id == 0 {
            assert_eq!(d, AdmissionDecision::Admit, "head kernel must be admitted");
        } else {
            assert_eq!(d, AdmissionDecision::Defer, "kernel {} should defer", k.id);
        }
    }
    engine.drain(&mut KerneletSelector);
    let rep = engine.finish_online();
    assert_eq!(rep.kernels_completed, 4, "deferred kernels must complete");
    let a = &rep.admission;
    assert_eq!(a.batch.deferrals, 3);
    assert_eq!(a.batch.deferred_unfinished, 0);
    assert_eq!(a.total_shed(), 0);
    // Head-of-line: the head finishes before any released kernel.
    for id in 1..4 {
        assert!(rep.completion[&0] <= rep.completion[&id], "kernel {id} jumped the head");
    }

    // Same run through run_source (the streaming front door).
    let instances = vec![
        KernelInstance::new(0, BenchmarkApp::PC.spec(), 0.0),
        KernelInstance::new(1, BenchmarkApp::MM.spec(), 0.0),
        KernelInstance::new(2, BenchmarkApp::MM.spec(), 0.0),
    ];
    let rep = EngineBuilder::new(&coord).admission(spec.build()).build().run_source(
        &mut KerneletSelector,
        &mut ReplaySource::from_instances("crafted", instances),
    );
    assert_eq!(rep.kernels_completed, 3);
    assert_eq!(rep.admission.batch.deferred_unfinished, 0);
}
