//! Differential and property tests over the streaming arrival layer.
//!
//! The load-bearing guarantee: an engine *pulled* by the Poisson
//! [`ArrivalSource`] reproduces the frozen pre-materialized-`Vec`
//! schedule bit-for-bit — the `Stream` path stays the oracle, so every
//! streaming scenario inherits the engine semantics the PR-1
//! differential tests pinned against the seed loops.

use kernelet::config::{GpuConfig, SelectorSpec};
use kernelet::coordinator::{Coordinator, Engine, KerneletSelector};
use kernelet::model::hetero::build_hetero_chain;
use kernelet::model::params::{ChainParams, SmEnv};
use kernelet::workload::{
    ArrivalSource, BurstySource, ClosedLoopSource, DiurnalSource, HeavyTailSource, Mix,
    PoissonSource, ReplaySource, Stream,
};

/// SATELLITE PROPERTY: `Stream::poisson` and the streaming Poisson
/// source produce identical instance sequences for the same seed —
/// ids, bit-exact arrival times, specs, order.
#[test]
fn poisson_source_and_stream_identical_sequences() {
    for (mix, per_app, lambda, seed) in [
        (Mix::CI, 100, 40.0, 1u64),
        (Mix::MI, 37, 250.0, 2),
        (Mix::MIX, 64, 999.0, 3),
        (Mix::ALL, 25, 77.7, 0xDEADBEEF),
    ] {
        let frozen = Stream::poisson(mix, per_app, lambda, seed);
        let mut src = PoissonSource::new(mix, per_app, lambda, seed);
        let mut streamed = Vec::new();
        while let Some(k) = src.next_arrival() {
            streamed.push(k);
        }
        assert_eq!(streamed.len(), frozen.len(), "{mix:?}");
        for (a, b) in streamed.iter().zip(&frozen.instances) {
            assert_eq!(a.id, b.id, "{mix:?}");
            assert_eq!(a.arrival_time.to_bits(), b.arrival_time.to_bits(), "{mix:?}");
            assert_eq!(a.spec, b.spec, "{mix:?}");
        }
    }
}

fn assert_reports_identical(
    name: &str,
    a: &kernelet::coordinator::ExecutionReport,
    b: &kernelet::coordinator::ExecutionReport,
) {
    assert_eq!(a.total_cycles, b.total_cycles, "{name}: total_cycles");
    assert_eq!(a.completion, b.completion, "{name}: completion map");
    assert_eq!(a.coschedule_rounds, b.coschedule_rounds, "{name}: rounds");
    assert_eq!(a.solo_slices, b.solo_slices, "{name}: solo slices");
    assert_eq!(a.slice_trace, b.slice_trace, "{name}: slice trace");
    assert_eq!(a.queue_depth, b.queue_depth, "{name}: queue depth timeline");
    assert_eq!(a.mean_turnaround_secs, b.mean_turnaround_secs, "{name}: turnaround");
    assert_eq!(a.utilization, b.utilization, "{name}: utilization");
    assert_eq!(a.incomplete, b.incomplete, "{name}: incomplete");
}

/// DIFFERENTIAL (acceptance): the engine driven by the Poisson
/// `ArrivalSource` reproduces the frozen pre-materialized-`Vec`
/// schedule bit-for-bit, for both policies, on both GPUs.
#[test]
fn engine_streamed_poisson_matches_frozen_vec_schedule() {
    for (gpu, seed) in [(GpuConfig::c2050(), 13u64), (GpuConfig::gtx680(), 14)] {
        let coord = Coordinator::new(&gpu);
        for (per_app, lambda) in [(6u32, 150.0), (10, 2000.0)] {
            let stream = Stream::poisson(Mix::MIX, per_app, lambda, seed);
            for policy in ["kernelet", "base"] {
                let sel = || SelectorSpec::from_name(policy).unwrap().build();
                let by_vec = Engine::new(&coord).run(sel().as_mut(), &stream);
                let mut src = PoissonSource::new(Mix::MIX, per_app, lambda, seed);
                let by_src = Engine::new(&coord).run_source(sel().as_mut(), &mut src);
                assert_reports_identical(
                    &format!("{}/{policy}/λ{lambda}", gpu.name),
                    &by_src,
                    &by_vec,
                );
            }
        }
    }
}

/// DIFFERENTIAL: replaying any stream through the source path is the
/// identity transform (saturated streams exercise the no-gap path).
#[test]
fn engine_replay_source_is_identity() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    for stream in [Stream::saturated(Mix::ALL, 3, 21), Stream::poisson(Mix::CI, 8, 90.0, 22)] {
        let by_vec = Engine::new(&coord).run(&mut KerneletSelector, &stream);
        let by_src = Engine::new(&coord)
            .run_source(&mut KerneletSelector, &mut ReplaySource::from_stream(&stream));
        assert_reports_identical("replay", &by_src, &by_vec);
    }
}

/// PROPERTY: every streaming scenario drains completely through the
/// engine — all emitted kernels complete, work is conserved, the
/// report is internally consistent.
#[test]
fn streaming_scenarios_complete_all_work() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let total = 60u64;
    let sources: Vec<Box<dyn ArrivalSource>> = vec![
        Box::new(BurstySource::new(Mix::MIX, total, [200.0, 1500.0], [0.05, 0.01], 51)),
        Box::new(DiurnalSource::new(Mix::MIX, total, 400.0, 0.9, 0.1, 52)),
        Box::new(HeavyTailSource::new(Mix::MIX, total, 300.0, 1.1, 53)),
        Box::new(ClosedLoopSource::new(Mix::MIX, 5, 1000.0, total, 54)),
    ];
    for mut src in sources {
        let name = src.scenario();
        let rep = Engine::new(&coord).run_source(&mut KerneletSelector, src.as_mut());
        assert_eq!(rep.kernels_completed, total as usize, "{name}");
        assert_eq!(rep.incomplete, 0, "{name}");
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-9, "{name}");
        // Slice trace timestamps stay monotone under streamed admission.
        for w in rep.slice_trace.windows(2) {
            assert!(w[0].end_cycles <= w[1].start_cycles + 1e-9, "{name}");
        }
        let dispatched: u64 = rep.blocks_dispatched().values().sum();
        assert!(dispatched > 0, "{name}");
    }
}

/// PROPERTY: a closed loop of N clients never has more than N kernels
/// pending, and its arrivals strictly follow the completions that
/// triggered them.
#[test]
fn closed_loop_backpressure_bounds_the_queue() {
    let coord = Coordinator::new(&GpuConfig::gtx680());
    for clients in [1usize, 2, 4] {
        let mut src = ClosedLoopSource::new(Mix::ALL, clients, 200.0, 40, 60 + clients as u64);
        let rep = Engine::new(&coord).run_source(&mut KerneletSelector, &mut src);
        assert_eq!(rep.kernels_completed, 40, "clients={clients}");
        assert!(
            rep.peak_queue_depth() <= clients,
            "clients={clients}: peak depth {}",
            rep.peak_queue_depth()
        );
    }
}

/// PROPERTY: determinism — every scenario replays bit-identically from
/// its seed through the full engine.
#[test]
fn streaming_scenarios_deterministic() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let build: [fn() -> Box<dyn ArrivalSource>; 3] = [
        || Box::new(BurstySource::new(Mix::MIX, 40, [150.0, 900.0], [0.08, 0.02], 71)),
        || Box::new(DiurnalSource::new(Mix::MIX, 40, 300.0, 0.8, 0.15, 72)),
        || Box::new(ClosedLoopSource::new(Mix::MIX, 3, 500.0, 40, 73)),
    ];
    for make in build {
        let mut a_src = make();
        let mut b_src = make();
        let a = Engine::new(&coord).run_source(&mut KerneletSelector, a_src.as_mut());
        let b = Engine::new(&coord).run_source(&mut KerneletSelector, b_src.as_mut());
        assert_reports_identical(a_src.scenario(), &a, &b);
    }
}

/// SATELLITE PROPERTY: heterogeneous product chains are row-stochastic
/// (rows sum to 1, no negative mass) across a grid of `ChainParams`,
/// under both SM environments.
#[test]
fn hetero_chain_stochastic_over_chainparams_grid() {
    let gpu = GpuConfig::c2050();
    let envs = [SmEnv::virtual_sm(&gpu), SmEnv::single_scheduler(&gpu)];
    let mut grid = Vec::new();
    for &units in &[1u32, 2, 5, 9] {
        for &group in &[1.0f64, 4.0, 8.0] {
            for &p_mem in &[0.0f64, 0.05, 0.35, 1.0] {
                for &sectors in &[4.0f64, 16.0] {
                    grid.push(ChainParams {
                        units,
                        group,
                        p_mem,
                        sectors_per_idle_unit: sectors,
                        uncoal_frac: 0.0,
                        sectors_coal: 4.0,
                        sectors_uncoal: 16.0,
                    });
                }
            }
        }
    }
    // Pair each grid point with a strided sample of partners (the full
    // cross is ~9k chains; a coprime stride still covers every
    // parameter combination on both sides).
    let mut checked = 0;
    for (i, p1) in grid.iter().enumerate() {
        for k in 0..5 {
            let p2 = &grid[(i + 1 + k * 19) % grid.len()];
            for env in &envs {
                let t = build_hetero_chain(p1, p2, env);
                assert_eq!(t.n, (p1.units as usize + 1) * (p2.units as usize + 1));
                t.validate(1e-8);
                checked += 1;
            }
        }
    }
    assert!(checked >= grid.len() * 5);
}
