//! Cold-path invariants: the fast paths introduced for sweep startup
//! must be *undetectable* in results.
//!
//! Four pins, mirroring the four pieces of the cold-path work:
//! 1. DIFFERENTIAL — the monotone binary search in
//!    `slicer::min_slice_size` returns the exact slice size of the
//!    frozen linear reference on an exhaustive (gpu, app, budget,
//!    seed) grid, while never simulating more candidates.
//! 2. PROPERTY — simulations through a reused (dirty) [`SimScratch`]
//!    are bitwise identical to fresh-engine runs for every entry
//!    point.
//! 3. PROPERTY — the structured solver's warm-started power method
//!    lands within 1e-9 (L1) of the dense solve, and a reused
//!    [`SolveScratch`] reproduces a fresh one's `auto` answer bit for
//!    bit.
//! 4. PROPERTY — `Coordinator::prewarm` + `warm_from` change cache
//!    temperature only: a warmed consumer answers `min_slice` and
//!    `best_split` bit-identically to a cold coordinator, and the
//!    prewarm accounting stays consistent (`filled = distinct −
//!    already_cached`, a second prewarm fills nothing).

use kernelet::config::GpuConfig;
use kernelet::coordinator::Coordinator;
use kernelet::kernel::BenchmarkApp;
use kernelet::model::homo::build_homo_chain;
use kernelet::model::params::SmEnv;
use kernelet::model::{ChainParams, Granularity, SolveScratch, Transition};
use kernelet::sim::{
    self, simulate_pair_rounds, simulate_pair_rounds_with, simulate_solo, simulate_solo_sliced,
    simulate_solo_sliced_with, simulate_solo_with, SimResult, SimScratch,
};
use kernelet::{slicer, workload::Mix};

const PROBE_SEED: u64 = sim::DEFAULT_SEED ^ 0x511CE;

fn gpus() -> [GpuConfig; 2] {
    [GpuConfig::c2050(), GpuConfig::gtx680()]
}

fn assert_bitwise_eq(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.cycles.to_bits(), b.cycles.to_bits(), "{ctx}: cycles diverged");
    assert_eq!(a.kernels, b.kernels, "{ctx}: per-kernel metrics diverged");
}

/// DIFFERENTIAL: binary search == frozen linear scan on every cell of
/// an exhaustive grid spanning degenerate budgets (nothing fits /
/// everything fits) and both the production probe seed and an
/// arbitrary one — same slice size, never more simulated candidates.
#[test]
fn binary_search_matches_linear_reference_exhaustively() {
    for gpu in &gpus() {
        for app in &BenchmarkApp::ALL {
            let spec = app.spec();
            for budget in [1e-9, 0.5, 2.0, slicer::DEFAULT_OVERHEAD_PCT, 8.0, 1e9] {
                for seed in [PROBE_SEED, 1] {
                    let (lin, lin_n) =
                        slicer::min_slice_size_linear_counted(gpu, &spec, budget, seed);
                    let (bin, bin_n) = slicer::min_slice_size_counted(gpu, &spec, budget, seed);
                    let ctx = format!("{} {} budget={budget} seed={seed}", gpu.name, spec.name);
                    assert_eq!(bin, lin, "{ctx}: sizes diverged");
                    assert!(
                        bin_n <= lin_n,
                        "{ctx}: binary simulated {bin_n} candidates, linear {lin_n}"
                    );
                }
            }
        }
    }
}

/// PROPERTY: one dirty scratch threaded through every simulation entry
/// point reproduces the fresh-engine answers bit for bit, in every
/// order. The scratch is deliberately polluted by a large pair-rounds
/// run before each comparison so stale buffer contents would show.
#[test]
fn scratch_reuse_is_bitwise_identical_to_fresh() {
    let mut dirty = SimScratch::new();
    for gpu in &gpus() {
        for app in &BenchmarkApp::ALL {
            let a = app.spec();
            let b = BenchmarkApp::MM.spec();
            // Pollute with a differently-shaped workload first.
            let _ = simulate_pair_rounds_with(&mut dirty, gpu, &b, 48, 3, &a, 48, 3, 2, 99);

            let solo = simulate_solo(gpu, &a, 42);
            let solo_s = simulate_solo_with(&mut dirty, gpu, &a, 42);
            assert_bitwise_eq(&solo, &solo_s, &format!("solo {} {}", gpu.name, a.name));

            let sliced = simulate_solo_sliced(gpu, &a, gpu.num_sms * 2, 42);
            let sliced_s = simulate_solo_sliced_with(&mut dirty, gpu, &a, gpu.num_sms * 2, 42);
            assert_bitwise_eq(&sliced, &sliced_s, &format!("sliced {} {}", gpu.name, a.name));

            let pair = simulate_pair_rounds(gpu, &a, 56, 3, &b, 56, 3, 4, 7);
            let pair_s = simulate_pair_rounds_with(&mut dirty, gpu, &a, 56, 3, &b, 56, 3, 4, 7);
            let ctx = format!("pair {} {}", gpu.name, a.name);
            assert_eq!(pair.cycles.to_bits(), pair_s.cycles.to_bits(), "{ctx}: cycles diverged");
            assert_eq!(pair.per_kernel, pair_s.per_kernel, "{ctx}: per-kernel metrics diverged");
        }
    }
}

/// Block-granularity chains for every app on `gpu` (the population the
/// scheduler's model layer solves).
fn app_chains(gpu: &GpuConfig) -> Vec<Transition> {
    let env = SmEnv::virtual_sm(gpu);
    BenchmarkApp::ALL
        .iter()
        .map(|a| {
            let spec = a.spec();
            let p = ChainParams::from_kernel(
                gpu,
                &spec,
                spec.blocks_per_sm(gpu),
                Granularity::Block,
                env.vsm_count,
            );
            build_homo_chain(&p, &env)
        })
        .collect()
}

/// PROPERTY: warm-started power iteration agrees with the dense solve
/// within 1e-9 (L1) on every app chain of both devices, and a reused
/// scratch's `auto` answer is bitwise equal to a fresh scratch's.
#[test]
fn warm_start_and_scratch_reuse_match_dense_solver() {
    for gpu in &gpus() {
        let chains = app_chains(gpu);
        let mut reused = SolveScratch::new();
        for t in &chains {
            let dense: Vec<f64> = reused.dense(t).to_vec();
            let warm: Vec<f64> = reused.power_warm(t, 1e-12, 20_000).to_vec();
            let l1: f64 = dense.iter().zip(&warm).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 <= 1e-9, "{}: warm start drifted {l1:.3e} from dense", gpu.name);

            let fresh: Vec<f64> = SolveScratch::new().auto(t).to_vec();
            let auto: Vec<f64> = reused.auto(t).to_vec();
            let same = fresh.len() == auto.len()
                && fresh.iter().zip(&auto).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{}: reused-scratch auto diverged from fresh", gpu.name);
        }
    }
}

/// PROPERTY: prewarm + warm_from only move cache temperature. A warmed
/// consumer answers `min_slice` and `best_split` bit-identically to a
/// cold coordinator, the transfer leaves the solo cache answering
/// without new misses, and the prewarm accounting is self-consistent.
#[test]
fn prewarm_and_warm_from_are_result_invisible() {
    let gpu = GpuConfig::c2050();
    let specs: Vec<_> = Mix::MIX.apps().iter().map(|a| a.spec()).collect();

    let donor = Coordinator::new(&gpu);
    let stats = donor.prewarm(&specs);
    assert_eq!(stats.filled, stats.distinct - stats.already_cached, "prewarm arithmetic");
    assert!(stats.distinct <= stats.requested, "dedup grew the request set");
    assert!(stats.filled > 0, "cold prewarm filled nothing");
    let again = donor.prewarm(&specs);
    assert_eq!(again.filled, 0, "second prewarm refilled cells");
    assert_eq!(again.already_cached, again.distinct, "second prewarm saw cold cells");

    let consumer = Coordinator::new(&gpu);
    let absorbed = consumer.warm_from(&donor);
    assert!(absorbed > 0, "nothing transferred");

    // Warm answers == cold answers, bit for bit.
    let cold = Coordinator::new(&gpu);
    for s in &specs {
        assert_eq!(consumer.min_slice(s), cold.min_slice(s), "{}: min_slice", s.name);
    }
    for i in 0..specs.len() {
        for j in i + 1..specs.len() {
            let warm = consumer.best_split(&specs[i], &specs[j]);
            let cold_v = cold.best_split(&specs[i], &specs[j]);
            match (warm, cold_v) {
                (None, None) => {}
                (Some((b1, b2, cipc, cp)), Some((c1, c2, cipc2, cp2))) => {
                    assert_eq!((b1, b2), (c1, c2), "split blocks diverged");
                    assert_eq!(cp.to_bits(), cp2.to_bits(), "cp diverged");
                    assert_eq!(
                        [cipc[0].to_bits(), cipc[1].to_bits()],
                        [cipc2[0].to_bits(), cipc2[1].to_bits()],
                        "cipc diverged"
                    );
                }
                (w, c) => panic!("feasibility diverged: warm={w:?} cold={c:?}"),
            }
        }
    }

    // The transfer left the solo cache warm: reads hit, no new misses.
    let (_, misses_before) = consumer.simcache.stats();
    for s in &specs {
        consumer.simcache.solo_full(s);
    }
    let (_, misses_after) = consumer.simcache.stats();
    assert_eq!(misses_before, misses_after, "warm_from left the solo cache cold");
}
