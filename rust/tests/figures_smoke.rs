//! Smoke + shape tests over the figure generators the unit tests do not
//! already cover (kept quick: FigOptions::quick()).

use kernelet::figures::{generate, FigOptions, ALL_IDS};

#[test]
fn fig4_correlations_positive() {
    let r = generate("fig4", &FigOptions::quick()).unwrap();
    // The notes carry pearson(pur_diff, cp) and pearson(mur_diff, cp);
    // the paper finds strong positive correlation for both.
    let parse = |s: &str| -> f64 { s.rsplit('=').next().unwrap().trim().parse().unwrap() };
    let rp = parse(&r.notes[0]);
    let rm = parse(&r.notes[1]);
    assert!(rp > 0.3, "pur corr too weak: {rp}");
    assert!(rm > 0.3, "mur corr too weak: {rm}");
}

#[test]
fn fig8_model_tracks_measurement() {
    let r = generate("fig8", &FigOptions::quick()).unwrap();
    assert_eq!(r.rows.len(), 56, "28 pairs x 2 GPUs");
    // The C2050 note carries the pearson between measured and predicted
    // concurrent IPC; demand a solid positive correlation.
    let corr: f64 = r.notes[0]
        .split("predicted)=")
        .nth(1)
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert!(corr > 0.7, "C2050 corr={corr}");
}

#[test]
fn fig9_fixed_ratio_also_tracks() {
    let r = generate("fig9", &FigOptions::quick()).unwrap();
    assert_eq!(r.rows.len(), 28);
    let meas = r.column_f64("measured_ipc");
    let pred = r.column_f64("predicted_ipc");
    let corr = kernelet::stats::pearson(&meas, &pred);
    assert!(corr > 0.7, "corr={corr}");
}

#[test]
fn fig11_underestimates_without_virtual_sm() {
    let r = generate("fig11", &FigOptions::quick()).unwrap();
    let meas = r.column_f64("measured_ipc");
    let pred = r.column_f64("predicted_ipc");
    // Paper: ignoring the multiple warp schedulers severely
    // underestimates GTX680 IPC — on average prediction << measurement.
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&pred) < mean(&meas) * 0.6,
        "pred={} meas={}",
        mean(&pred),
        mean(&meas)
    );
}

#[test]
fn fig12_cp_prediction_correlates() {
    let r = generate("fig12", &FigOptions::quick()).unwrap();
    let meas = r.column_f64("measured_cp");
    let pred = r.column_f64("predicted_cp");
    let corr = kernelet::stats::pearson(&meas, &pred);
    // Full-scale run measured 0.39 (EXPERIMENTS.md §Fig. 12): CP
    // compounds four model outputs, so its correlation is weaker than
    // the IPC-level agreement; the paper's claim is only that it
    // suffices to rank schedules (verified end-to-end by fig13).
    assert!(corr > 0.25, "corr={corr}");
}

#[test]
fn figure_registry_is_complete() {
    // Adding a figure means growing ALL_IDS; this pins the count so a
    // new generator cannot be wired into `generate` but left out of
    // `figure all` (or vice versa — generate() rejects unknown ids).
    assert_eq!(ALL_IDS.len(), 20, "figure registry drifted: {ALL_IDS:?}");
    for id in ["routing", "tenancy", "resilience"] {
        assert!(ALL_IDS.contains(&id), "{id} missing from ALL_IDS");
    }
}

#[test]
fn routing_figure_smokes() {
    let opts = FigOptions { instances_per_app: 6, mc_samples: 1, ..Default::default() };
    let r = generate("routing", &opts).unwrap();
    assert_eq!(r.id, "routing");
    assert!(!r.rows.is_empty());
    let policy = r.col("policy");
    for p in ["roundrobin", "sloaware", "efc"] {
        assert!(r.rows.iter().any(|row| row[policy] == p), "missing policy {p}");
    }
}

#[test]
fn tenancy_figure_smokes() {
    let opts = FigOptions { instances_per_app: 6, mc_samples: 1, ..Default::default() };
    let r = generate("tenancy", &opts).unwrap();
    assert_eq!(r.id, "tenancy");
    assert!(!r.rows.is_empty());
    // Every row carries a tenant label and a parseable goodput.
    let goodput = r.column_f64("goodput_kps");
    assert!(goodput.iter().all(|g| g.is_finite() && *g >= 0.0));
}

#[test]
fn resilience_figure_smokes() {
    let opts = FigOptions { instances_per_app: 6, mc_samples: 1, ..Default::default() };
    let r = generate("resilience", &opts).unwrap();
    assert_eq!(r.id, "resilience");
    // 3 drills x 2 policies + the flash-crowd pair.
    assert_eq!(r.rows.len(), 8);
    let (mode, stranded) = (r.col("mode"), r.col("stranded"));
    for m in ["none", "drain", "slowdown", "flash-fixed", "flash-auto"] {
        assert!(r.rows.iter().any(|row| row[mode] == m), "missing mode {m}");
    }
    // The control rows ran an empty plan: nothing stranded anywhere.
    assert!(r.rows.iter().all(|row| row[stranded] == "0"), "stranded kernels in smoke run");
}

#[test]
fn all_reports_save_tsv() {
    let dir = std::env::temp_dir().join("kernelet_figs_smoke");
    let _ = std::fs::remove_dir_all(&dir);
    // Only the cheap ones — full coverage happens in `make figures`.
    for id in ["table2", "fig10"] {
        let r = generate(id, &FigOptions::quick()).unwrap();
        r.save_tsv(&dir).unwrap();
        let content = std::fs::read_to_string(dir.join(format!("{id}.tsv"))).unwrap();
        assert!(content.lines().count() >= 2, "{id}");
    }
}
