//! Hot-path index invariants: every incrementally-maintained structure
//! on the dispatch path is pinned **bit-identical** to the O(pending)
//! scan it replaced.
//!
//! The `reference` module below is a frozen copy of the scan-based
//! `DeadlineSelector` as it stood before the EDF index — it rescans the
//! pending set at every entry point and re-touches the simulator cache
//! for every estimate. The indexed selector must make exactly the same
//! decisions, producing exactly the same reports, on every arrival
//! scenario the crate ships. The other tests pin the ETA price memo
//! against fresh-model projections, the batched `run_source` completion
//! loop against the frozen `Engine::run` Vec path, and the parallel
//! sweep driver against its serial loop.

use kernelet::config::GpuConfig;
use kernelet::coordinator::{
    Coordinator, DeadlineSelector, Engine, EtaModel, ExecutionReport, KerneletSelector,
    PreemptCost, Selector,
};
use kernelet::kernel::BenchmarkApp;
use kernelet::sweep::run_cells_with;
use kernelet::workload::{
    scenario_source, ClosedLoopSource, Mix, QosMix, ReplaySource, Stream, SCENARIO_NAMES,
};

/// Frozen scan-based predecessor of the indexed `DeadlineSelector`.
/// Deliberately naive: no EDF index, no estimate memo, no per-decision
/// urgency cache — every entry point rescans `ctx.pending` and prices
/// every deadlined kernel through `SchedCtx::est_remaining_secs`. This
/// is the oracle the index must match decision for decision.
mod reference {
    use kernelet::coordinator::{
        Decision, KerneletSelector, PreemptCost, PreemptPoint, SchedCtx, Selector,
    };
    use kernelet::kernel::KernelInstance;

    pub struct ScanDeadlineSelector {
        inner: KerneletSelector,
        urgency_factor: f64,
        preempt: Option<PreemptCost>,
    }

    impl ScanDeadlineSelector {
        pub fn new() -> Self {
            Self { inner: KerneletSelector, urgency_factor: 2.0, preempt: None }
        }

        pub fn with_preemption(mut self, cost: PreemptCost) -> Self {
            self.preempt = Some(cost);
            self
        }
    }

    impl Default for ScanDeadlineSelector {
        fn default() -> Self {
            Self::new()
        }
    }

    impl ScanDeadlineSelector {
        fn deadline_pending(ctx: &SchedCtx<'_, '_>) -> bool {
            ctx.pending.iter().any(|k| k.qos.deadline.is_some())
        }

        fn scan_urgent(&self, ctx: &SchedCtx<'_, '_>) -> Option<u64> {
            let mut best: Option<(f64, u64)> = None;
            for &k in ctx.pending {
                let Some(ttd) = k.time_to_deadline(ctx.now_secs) else { continue };
                let est = ctx.est_remaining_secs(k);
                if ttd > self.urgency_factor * est {
                    continue;
                }
                let slack = ttd - est;
                if best.map_or(true, |(s, _)| slack < s) {
                    best = Some((slack, k.id));
                }
            }
            best.map(|(_, id)| id)
        }

        fn earliest_urgency_secs(
            &self,
            ctx: &SchedCtx<'_, '_>,
            exclude: Option<u64>,
        ) -> Option<f64> {
            let mut earliest: Option<f64> = None;
            for &k in ctx.pending {
                let Some(deadline) = k.qos.deadline else { continue };
                if Some(k.id) == exclude {
                    continue;
                }
                let t_u = deadline - self.urgency_factor * ctx.est_remaining_secs(k);
                if earliest.map_or(true, |e| t_u < e) {
                    earliest = Some(t_u);
                }
            }
            earliest
        }

        fn pending_deadline_pair(&self, ctx: &SchedCtx<'_, '_>, d: Decision) -> Decision {
            let Some(cost) = self.preempt else {
                return Decision { rounds_cap: Some(1), ..d };
            };
            match self.earliest_urgency_secs(ctx, None) {
                Some(t_u) => {
                    let at = t_u - cost.break_even_secs();
                    if at <= ctx.now_secs {
                        Decision { rounds_cap: Some(1), ..d }
                    } else {
                        Decision {
                            preempt: Some(PreemptPoint {
                                at_secs: at,
                                relaunch_secs: cost.relaunch_secs,
                            }),
                            ..d
                        }
                    }
                }
                None => Decision { rounds_cap: Some(1), ..d },
            }
        }
    }

    impl Selector for ScanDeadlineSelector {
        fn name(&self) -> &'static str {
            "scan-deadline"
        }

        fn select(&mut self, ctx: &SchedCtx<'_, '_>) -> Option<Decision> {
            match self.scan_urgent(ctx) {
                None => match self.inner.select(ctx) {
                    Some(d) if Self::deadline_pending(ctx) => {
                        Some(self.pending_deadline_pair(ctx, d))
                    }
                    other => other,
                },
                Some(u) => match self.inner.select(ctx) {
                    Some(d) if d.k1 == u || d.k2 == u => {
                        Some(Decision { rounds_cap: Some(1), ..d })
                    }
                    _ => None,
                },
            }
        }

        fn solo_pick(&mut self, ctx: &SchedCtx<'_, '_>) -> Option<u64> {
            match self.scan_urgent(ctx) {
                Some(u) => Some(u),
                None => self.inner.solo_pick(ctx),
            }
        }

        fn solo_slice(&mut self, ctx: &SchedCtx<'_, '_>, head: &KernelInstance) -> u32 {
            if Self::deadline_pending(ctx) || ctx.more_arrivals {
                ctx.coord.min_slice(&head.spec).max(head.spec.grid_blocks / 4)
            } else {
                head.remaining_blocks()
            }
        }

        fn solo_plan(
            &mut self,
            ctx: &SchedCtx<'_, '_>,
            head: &KernelInstance,
        ) -> (u32, Option<PreemptPoint>) {
            if let Some(cost) = self.preempt {
                if !ctx.more_arrivals && Self::deadline_pending(ctx) {
                    match self.earliest_urgency_secs(ctx, Some(head.id)) {
                        Some(t_u) => {
                            let at = t_u - cost.break_even_secs();
                            if at > ctx.now_secs {
                                return (
                                    head.remaining_blocks(),
                                    Some(PreemptPoint {
                                        at_secs: at,
                                        relaunch_secs: cost.relaunch_secs,
                                    }),
                                );
                            }
                        }
                        None => return (head.remaining_blocks(), None),
                    }
                }
            }
            (self.solo_slice(ctx, head), None)
        }
    }
}

fn assert_reports_identical(label: &str, a: &ExecutionReport, b: &ExecutionReport) {
    assert_eq!(a.kernels_completed, b.kernels_completed, "{label}: completed diverged");
    assert_eq!(a.incomplete, b.incomplete, "{label}: incomplete diverged");
    assert_eq!(
        a.total_cycles.to_bits(),
        b.total_cycles.to_bits(),
        "{label}: makespan diverged ({} vs {})",
        a.total_cycles,
        b.total_cycles
    );
    assert_eq!(a.completion, b.completion, "{label}: completion times diverged");
    assert_eq!(a.slice_trace, b.slice_trace, "{label}: dispatch sequence diverged");
    assert_eq!(a.queue_depth, b.queue_depth, "{label}: decision trace diverged");
    assert_eq!(a.coschedule_rounds, b.coschedule_rounds, "{label}: rounds diverged");
    assert_eq!(a.solo_slices, b.solo_slices, "{label}: solo slices diverged");
    assert_eq!(a.preemptions, b.preemptions, "{label}: preemptions diverged");
    assert_eq!(
        a.qos.total_deadline_misses(),
        b.qos.total_deadline_misses(),
        "{label}: deadline misses diverged"
    );
}

/// Latency share whose deadlines sit near the urgency window of a
/// typical kernel, so the selectors exercise the urgent jump, the
/// pending-deadline hold, and the comfortable-slack defer on the same
/// run.
fn test_qos(coord: &Coordinator) -> QosMix {
    let est_mm = coord.gpu.cycles_to_secs(coord.simcache.solo_full(&BenchmarkApp::MM.spec()));
    QosMix::latency_share(0.5, 3.0 * est_mm)
}

/// Tentpole pin: the EDF-indexed `DeadlineSelector` is decision- and
/// report-identical to the frozen scan-based predecessor on every
/// arrival scenario, with and without mid-slice preemption.
#[test]
fn indexed_deadline_selector_matches_scan_reference_on_all_scenarios() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let qos = test_qos(&coord);
    let est_mm = coord.gpu.cycles_to_secs(coord.simcache.solo_full(&BenchmarkApp::MM.spec()));
    // Offer work faster than one device drains it so the pending set
    // (and the index) actually grows: ~6 kernels' worth per second.
    let rate = 6.0 / est_mm;
    let cost = PreemptCost::for_gpu(&coord.gpu);
    for scenario in SCENARIO_NAMES {
        for preempting in [false, true] {
            let run = |sel: &mut dyn Selector| -> ExecutionReport {
                let mut src = scenario_source(scenario, Mix::MIX, 4, rate, 0x1D8, qos)
                    .expect("scenario source");
                Engine::new(&coord).run_source(sel, src.as_mut())
            };
            let indexed = if preempting {
                run(&mut DeadlineSelector::new().with_preemption(cost))
            } else {
                run(&mut DeadlineSelector::new())
            };
            let scanned = if preempting {
                run(&mut reference::ScanDeadlineSelector::new().with_preemption(cost))
            } else {
                run(&mut reference::ScanDeadlineSelector::new())
            };
            let label = format!("{scenario} (preempting={preempting})");
            assert!(indexed.kernels_completed > 0, "{label}: empty run proves nothing");
            assert_reports_identical(&label, &indexed, &scanned);
        }
    }
}

/// A selector instance is reusable across engines (the fleet dispatcher
/// does exactly that): the index's cursor-reset guard must keep the
/// second run identical to the scan reference too.
#[test]
fn indexed_selector_reused_across_engines_matches_scan_reference() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let qos = test_qos(&coord);
    let mut stream = Stream::saturated(Mix::MIX, 3, 0xBEE);
    for k in &mut stream.instances {
        k.qos = qos.stamp(k.id, k.arrival_time);
    }
    let mut indexed = DeadlineSelector::new();
    let mut scanned = reference::ScanDeadlineSelector::new();
    for pass in 0..3 {
        let a = Engine::new(&coord)
            .run_source(&mut indexed, &mut ReplaySource::from_stream(&stream));
        let b = Engine::new(&coord)
            .run_source(&mut scanned, &mut ReplaySource::from_stream(&stream));
        assert_reports_identical(&format!("engine handoff pass {pass}"), &a, &b);
    }
}

/// The ETA price memo is invisible: a model that has priced the same
/// queue many times projects bit-identically to a brand-new model, and
/// a repeated projection (a guaranteed memo hit) reproduces itself.
#[test]
fn eta_price_memo_projections_match_fresh_model() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let stream = Stream::poisson(Mix::MIX, 10, 1500.0, 0xE7A1);
    let mut engine = Engine::new(&coord);
    let mut sel = KerneletSelector;
    let mut warm = EtaModel::new();
    let mut projections = 0usize;
    for k in stream.arrivals() {
        engine.run_until(&mut sel, k.arrival_time, true);
        let clock = engine.clock_secs();
        let now = clock.max(k.arrival_time);
        let hot = warm.projected_finish_secs(&coord, engine.pending(), clock, now, &k);
        let fresh =
            EtaModel::new().projected_finish_secs(&coord, engine.pending(), clock, now, &k);
        assert_eq!(
            hot.to_bits(),
            fresh.to_bits(),
            "price memo diverged from a fresh model at t={now} (pending={})",
            engine.pending().len()
        );
        let again = warm.projected_finish_secs(&coord, engine.pending(), clock, now, &k);
        assert_eq!(again.to_bits(), hot.to_bits(), "memo hit not idempotent at t={now}");
        projections += 1;
        engine.submit(k);
    }
    engine.drain(&mut sel);
    assert_eq!(projections, stream.len());
}

/// The batched completion loop in `run_source` (feed + re-peek only
/// when a decision actually completed something) stays bit-identical to
/// the frozen `Engine::run` Vec path — including under preemption pins,
/// whose cut-and-relaunch completions land mid-block.
#[test]
fn batched_run_source_matches_frozen_vec_path_under_preemption() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let qos = test_qos(&coord);
    let cost = PreemptCost::for_gpu(&coord.gpu);
    for (name, mut stream) in [
        ("saturated", Stream::saturated(Mix::MIX, 4, 0x7E)),
        ("poisson", Stream::poisson(Mix::MIX, 6, 900.0, 0x7F)),
    ] {
        for k in &mut stream.instances {
            k.qos = qos.stamp(k.id, k.arrival_time);
        }
        let vec_path = Engine::new(&coord)
            .run(&mut DeadlineSelector::new().with_preemption(cost), &stream);
        let streamed = Engine::new(&coord).run_source(
            &mut DeadlineSelector::new().with_preemption(cost),
            &mut ReplaySource::from_stream(&stream),
        );
        assert_reports_identical(name, &vec_path, &streamed);
    }
}

/// Closed-loop sources are the one case where batching could skew the
/// feedback cadence (arrivals depend on completions): the run must be
/// reproducible from its seed, and every issued job completes.
#[test]
fn batched_closed_loop_run_is_deterministic() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let run = || {
        let mut src = ClosedLoopSource::new(Mix::MIX, 4, 50.0, 24, 0xC10);
        Engine::new(&coord).run_source(&mut KerneletSelector, &mut src)
    };
    let a = run();
    let b = run();
    assert_eq!(a.kernels_completed, 24);
    assert_reports_identical("closed-loop", &a, &b);
}

/// The parallel sweep driver is byte-identical to the serial loop on a
/// real figure-style sweep: a scenario × load grid of full engine runs
/// sharing one coordinator (so the parallel pass also exercises
/// concurrent population of the shared measurement caches).
#[test]
fn parallel_sweep_matches_serial_on_engine_grid() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let est_mm = coord.gpu.cycles_to_secs(coord.simcache.solo_full(&BenchmarkApp::MM.spec()));
    let mut cells: Vec<(&str, f64)> = Vec::new();
    for scenario in SCENARIO_NAMES {
        for load in [2.0, 6.0] {
            cells.push((scenario, load / est_mm));
        }
    }
    let cell = |i: usize, &(scenario, rate): &(&str, f64)| -> (u64, usize, Vec<(f64, usize)>) {
        let mut src =
            scenario_source(scenario, Mix::MIX, 3, rate, 0x5EED + i as u64, QosMix::ALL_BATCH)
                .expect("scenario source");
        let rep = Engine::new(&coord).run_source(&mut KerneletSelector, src.as_mut());
        (rep.total_cycles.to_bits(), rep.kernels_completed, rep.queue_depth)
    };
    let serial = run_cells_with(&cells, 1, cell);
    let parallel = run_cells_with(&cells, 4, cell);
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(s, p, "cell {i} ({:?}) diverged between serial and parallel", cells[i]);
    }
}
