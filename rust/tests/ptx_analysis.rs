//! Integration tests for the PTX slice-safety analyzer and its wiring
//! into the scheduler: text round-trips, liveness soundness, pinned
//! sample verdicts, the differential rectify-verifier, and the
//! end-to-end guarantee that an `Unsliceable` kernel is never
//! dispatched sliced or co-scheduled.

use std::collections::HashMap;

use kernelet::config::GpuConfig;
use kernelet::coordinator::{run_kernelet, Coordinator};
use kernelet::kernel::{BenchmarkApp, KernelInstance};
use kernelet::ptx::ast::Kernel;
use kernelet::ptx::liveness::{build_cfg, liveness};
use kernelet::ptx::{
    analyze_ptx, emit, parse_kernel, rectify, samples, verify_rectify, RectifyOptions,
    SliceVerdict, UnsafeReason,
};
use kernelet::workload::Stream;

/// Kernel equality modulo register-declaration order: emit groups
/// `.reg` lines by type, so a parse -> emit -> parse trip may reorder
/// declarations without changing meaning.
fn assert_same_kernel(a: &Kernel, b: &Kernel, ctx: &str) {
    assert_eq!(a.name, b.name, "{ctx}: name");
    assert_eq!(a.params, b.params, "{ctx}: params");
    assert_eq!(a.body, b.body, "{ctx}: body");
    let mut ra = a.regs.clone();
    let mut rb = b.regs.clone();
    ra.sort_by(|x, y| x.0.cmp(&y.0));
    rb.sort_by(|x, y| x.0.cmp(&y.0));
    assert_eq!(ra, rb, "{ctx}: register declarations");
}

/// Parse -> emit -> parse is the identity (modulo register grouping)
/// for every sample, and for every rectified form of every sample —
/// the property that makes "hand the rewritten PTX back to the driver"
/// safe.
#[test]
fn parse_emit_parse_roundtrip_every_sample() {
    for (name, src) in samples::all() {
        let k = parse_kernel(src).unwrap();
        let re = parse_kernel(&emit::emit(&k)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_same_kernel(&k, &re, name);
        for (dims, opts) in [(1, RectifyOptions::one_d()), (2, RectifyOptions::two_d())] {
            let s = rectify(&k, &opts);
            let re = parse_kernel(&emit::emit(&s))
                .unwrap_or_else(|e| panic!("{name} rectified {dims}-D: {e}"));
            assert_same_kernel(&s, &re, &format!("{name} rectified {dims}-D"));
        }
    }
}

/// Liveness soundness: a register read by an instruction must be live
/// immediately before it on every path that reaches it — within a
/// block that is the live-out of the previous instruction, and across
/// a CFG edge it is the live-out of the predecessor block's last
/// instruction. This exercises the fixpoint propagation, not just the
/// local transfer function.
#[test]
fn liveness_covers_every_use_on_every_path() {
    for (name, src) in samples::all() {
        let k = parse_kernel(src).unwrap();
        let live_out = liveness(&k.body);
        let cfg = build_cfg(&k.body);
        for block in &cfg.blocks {
            // Within-block: uses of body[i] are live out of body[i-1].
            for i in block.range.clone().skip(1) {
                for u in k.body[i].uses() {
                    assert!(
                        live_out[i - 1].contains(u),
                        "{name}: use of {u:?} at inst {i} not live out of inst {}",
                        i - 1
                    );
                }
            }
            // Cross-edge: uses of each successor's first instruction
            // are live out of this block's last instruction.
            if block.range.is_empty() {
                continue;
            }
            let last = block.range.end - 1;
            for &s in &block.succs {
                let srange = &cfg.blocks[s].range;
                if srange.is_empty() {
                    continue;
                }
                for u in k.body[srange.start].uses() {
                    assert!(
                        live_out[last].contains(u),
                        "{name}: use of {u:?} at block-{s} entry not live across \
                         the edge from inst {last}"
                    );
                }
            }
        }
    }
}

/// Every sample kernel has a pinned analyzer verdict. These are the
/// ground-truth classifications the CLI table and the scheduler gate
/// are built on; a verdict drift here is a behaviour change, not a
/// refactor.
#[test]
fn sample_verdicts_are_pinned() {
    let expected: &[(&str, SliceVerdict)] = &[
        ("matrix_add", SliceVerdict::SliceableWithRectify),
        ("saxpy", SliceVerdict::SliceableWithRectify),
        ("gather", SliceVerdict::SliceableWithRectify),
        ("mix_rounds", SliceVerdict::SliceableWithRectify),
        ("histogram", SliceVerdict::Unsliceable(UnsafeReason::GlobalAtomic)),
        ("tail_flag", SliceVerdict::Unsliceable(UnsafeReason::GridDependentBranch)),
        ("block_barrier", SliceVerdict::SliceableWithRectify),
    ];
    let mut seen = HashMap::new();
    for (name, src) in samples::all() {
        seen.insert(name, analyze_ptx(src).unwrap().verdict);
    }
    assert_eq!(seen.len(), expected.len(), "sample set changed; re-pin verdicts");
    for (name, want) in expected {
        assert_eq!(seen[name], *want, "{name}: verdict drifted");
    }
}

/// The differential rectify-verifier proves bit-identical memory for
/// every sample under the sequential interpreter (2 grids x 3 slice
/// sizes each). The unsliceable samples pass too — sequential
/// execution hides their concurrency hazards, which is exactly why
/// the static verdict, not this oracle, gates the scheduler.
#[test]
fn rectify_verifier_covers_every_sample() {
    for (name, src) in samples::all() {
        let k = parse_kernel(src).unwrap();
        let compared = verify_rectify(&k).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(compared, 6, "{name}: expected 2 grids x 3 slice sizes");
    }
}

/// End-to-end scheduler differential: with no analysis registered a
/// TEA+PC stream co-schedules (TEA appears in a paired slice record);
/// after registering an `Unsliceable` analysis under TEA's name, the
/// same stream never dispatches TEA sliced or paired — every TEA
/// record is a solo whole-grid launch.
#[test]
fn scheduler_never_dispatches_unsliceable_sliced() {
    let gpu = GpuConfig::c2050();
    let stream = Stream {
        instances: vec![
            KernelInstance::new(0, BenchmarkApp::TEA.spec(), 0.0),
            KernelInstance::new(1, BenchmarkApp::PC.spec(), 0.0),
        ],
    };
    let tea_grid = BenchmarkApp::TEA.spec().grid_blocks;

    // Ungated: the pair is profitable (pinned by the greedy tests), so
    // TEA must show up co-scheduled.
    let coord = Coordinator::new(&gpu);
    let r = run_kernelet(&coord, &stream);
    assert_eq!(r.kernels_completed, 2);
    let tea_paired = r
        .slice_trace
        .iter()
        .any(|s| (s.k1 == 0 && s.k2.is_some()) || s.k2.map_or(false, |(id, _)| id == 0));
    assert!(tea_paired, "ungated run should co-schedule TEA with PC");

    // Gated: an Unsliceable verdict registered under TEA's name. The
    // verdict itself comes from the analyzer (run on the global-atomic
    // histogram sample), not hand-rolled.
    let gated = Coordinator::new(&gpu);
    let mut analysis = analyze_ptx(samples::HISTOGRAM).unwrap();
    assert!(!analysis.sliceable());
    analysis.name = "TEA".to_string();
    gated.register_analysis("TEA", analysis);
    let r = run_kernelet(&gated, &stream);
    assert_eq!(r.kernels_completed, 2);
    for s in &r.slice_trace {
        if s.k1 == 0 {
            assert_eq!(s.k2, None, "unsliceable TEA must never be paired");
            assert_eq!(
                s.blocks1, tea_grid,
                "unsliceable TEA must dispatch its whole grid in one launch"
            );
        }
        if let Some((id, _)) = s.k2 {
            assert_ne!(id, 0, "unsliceable TEA must never appear as a partner slice");
        }
    }
    let tea_records = r.slice_trace.iter().filter(|s| s.k1 == 0).count();
    assert_eq!(tea_records, 1, "whole-grid dispatch means exactly one TEA record");
}
