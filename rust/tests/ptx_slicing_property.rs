//! Property tests for the PTX slicing transform: for every sample
//! kernel and RANDOM slice partitions / launch geometries, sliced
//! execution through the interpreter is bit-identical to the original
//! launch (the paper's §4.1 safety claim under the §2.2
//! block-independence assumption).

use kernelet::ptx::interp::{Args, LaunchConfig};
use kernelet::ptx::{launch, parse_kernel, rectify, samples, Machine, RectifyOptions};
use kernelet::stats::Xoshiro256;

/// Random contiguous partition of `total` into slices of 1..=max_slice.
fn random_partition(rng: &mut Xoshiro256, total: u32, max_slice: u32) -> Vec<u32> {
    let mut out = Vec::new();
    let mut left = total;
    while left > 0 {
        let s = (1 + rng.below(max_slice as u64) as u32).min(left);
        out.push(s);
        left -= s;
    }
    out
}

fn init_machine(rng: &mut Xoshiro256, threads: usize) -> Machine {
    let mut m = Machine::new(64 * 1024);
    let idx: Vec<u32> = {
        // A random permutation keeps gather targets in range.
        let mut v: Vec<u32> = (0..threads as u32).collect();
        rng.shuffle(&mut v);
        v
    };
    m.write_u32s(0, &idx);
    let fdata: Vec<f32> = (0..threads).map(|_| rng.range_f64(-4.0, 4.0) as f32).collect();
    m.write_f32s(16 * 1024, &fdata);
    let fdata2: Vec<f32> = (0..threads).map(|_| rng.range_f64(-4.0, 4.0) as f32).collect();
    m.write_f32s(32 * 1024, &fdata2);
    m
}

fn args_for(name: &str, grid: (u32, u32), block: (u32, u32), threads: usize) -> Args {
    match name {
        "matrix_add" => vec![16 * 1024, 32 * 1024, (grid.0 * block.0) as u64],
        "saxpy" => vec![16 * 1024, 32 * 1024, (1.5f32).to_bits() as u64, threads as u64],
        "gather" => vec![0, 16 * 1024, 32 * 1024],
        "mix_rounds" => vec![0, 5],
        // The slicing-unsafe samples still pass the *sequential*
        // differential: the interpreter runs blocks in the same global
        // order either way, and rectification substitutes the original
        // grid extent for %nctaid. This is exactly why the static
        // analyzer, not this oracle, is the authority on their
        // verdicts (see ptx::analyze).
        "histogram" => vec![0, 48 * 1024],
        "tail_flag" => vec![48 * 1024],
        "block_barrier" => vec![0, 48 * 1024],
        other => panic!("unknown sample {other}"),
    }
}

#[test]
fn sliced_equals_whole_for_random_partitions() {
    let mut rng = Xoshiro256::new(0x9A9A);
    for (name, src) in samples::all() {
        let kernel = parse_kernel(src).unwrap();
        let is_2d = name == "matrix_add";
        let opts = if is_2d { RectifyOptions::two_d() } else { RectifyOptions::one_d() };
        let sliced = rectify(&kernel, &opts);
        for trial in 0..6 {
            let (grid, block): ((u32, u32), (u32, u32)) = if is_2d {
                let g = 2 + rng.below(4) as u32;
                ((g, g), (8, 8))
            } else {
                ((2 + rng.below(14) as u32, 1), (16, 1))
            };
            let threads = (grid.0 * grid.1 * block.0 * block.1) as usize;
            let args = args_for(name, grid, block, threads);
            let init = init_machine(&mut rng, threads);

            let mut whole = init.clone();
            launch(&kernel, LaunchConfig { grid, block }, &args, &mut whole)
                .unwrap_or_else(|e| panic!("{name} trial {trial}: {e}"));

            let total_blocks = grid.0 * grid.1;
            let parts = random_partition(&mut rng, total_blocks, 5);
            let mut slicedm = init.clone();
            let mut next = 0u32;
            for part in parts {
                let mut sargs = args.clone();
                if is_2d {
                    sargs.extend([
                        (next % grid.0) as u64,
                        grid.0 as u64,
                        (next / grid.0) as u64,
                        grid.1 as u64,
                    ]);
                } else {
                    sargs.extend([next as u64, grid.0 as u64]);
                }
                launch(&sliced, LaunchConfig { grid: (part, 1), block }, &sargs, &mut slicedm)
                    .unwrap_or_else(|e| panic!("{name} trial {trial}: {e}"));
                next += part;
            }
            assert_eq!(next, total_blocks);
            assert_eq!(
                whole.memory, slicedm.memory,
                "{name} trial {trial}: sliced run diverged"
            );
        }
    }
}

/// Rectified kernels survive an emit -> parse -> emit round trip (the
/// "hand the PTX back to the driver" path).
#[test]
fn rectified_text_roundtrip_stable() {
    for (name, src) in samples::all() {
        let k = parse_kernel(src).unwrap();
        for opts in [RectifyOptions::one_d(), RectifyOptions::two_d()] {
            let s1 = rectify(&k, &opts);
            let t1 = kernelet::ptx::emit::emit(&s1);
            let s2 = parse_kernel(&t1).unwrap_or_else(|e| panic!("{name}: {e}"));
            let t2 = kernelet::ptx::emit::emit(&s2);
            assert_eq!(t1, t2, "{name}: emit not a fixed point");
        }
    }
}

/// The wrap-around loop normalizes out-of-range x offsets into y
/// (Fig. 3c): launching the 2-D kernel with a linear offset past the
/// end of a row must land on the right (x, y) block.
#[test]
fn two_d_wraparound_correct() {
    let kernel = parse_kernel(samples::MATRIX_ADD).unwrap();
    let sliced = rectify(&kernel, &RectifyOptions::two_d());
    let (grid, block) = ((4u32, 4u32), (8u32, 8u32));
    let width = grid.0 * block.0;
    let total = (width * width) as usize;
    let mut rng = Xoshiro256::new(3);
    let init = init_machine(&mut rng, total);
    let args = args_for("matrix_add", grid, block, total);

    let mut whole = init.clone();
    launch(&kernel, LaunchConfig { grid, block }, &args, &mut whole).unwrap();

    // One slice per block, but pass the offset UN-normalized: x = k,
    // y = 0 for all 16 blocks. The kernel's wrap loop must fix it.
    let mut slicedm = init.clone();
    for k in 0..grid.0 * grid.1 {
        let mut sargs = args.clone();
        sargs.extend([k as u64, grid.0 as u64, 0u64, grid.1 as u64]);
        launch(&sliced, LaunchConfig { grid: (1, 1), block }, &sargs, &mut slicedm).unwrap();
    }
    assert_eq!(whole.memory, slicedm.memory, "wrap-around normalization broken");
}
