//! Fleet-resilience invariants: the differential pin (an EMPTY
//! [`FaultPlan`] installed on the dispatcher is bit-identical to the
//! faultless dispatcher on every scenario × routing policy, preemption
//! on and off), kernel conservation under seeded churn (every arrival
//! is completed, shed, deferred, or stranded — and counted exactly
//! once), the drain drill (withdrawn work re-routes to survivors;
//! draining the *last* device strands instead of losing silently), the
//! slowdown drill (only ETA calibration notices a degraded device, and
//! the calibrated router beats the uncalibrated one on the victim
//! tail), and the autoscaler drills (scale-up on sustained shedding,
//! scale-down on idle).

use kernelet::config::{DispatchSpec, GpuConfig};
use kernelet::coordinator::{
    AdmissionSpec, AutoscalerSpec, Coordinator, DispatchPolicy, FaultEvent, FaultPlan,
    MultiGpuDispatcher, MultiGpuReport, PreemptCost, ShedPoint,
};
use kernelet::figures::throughput::base_capacity_kps;
use kernelet::workload::{scenario_source, Mix, QosMix, SCENARIO_NAMES};

const SEED: u64 = 0xFA_0807;

/// Fleet-wide completed-kernel count.
fn completed(rep: &MultiGpuReport) -> usize {
    rep.reports.iter().map(|r| r.kernels_completed).sum()
}

/// The conservation identity every fault-injected run must satisfy:
/// `completed + shed + deferred_unfinished + stranded + incomplete`
/// partitions the arrivals exactly — churn may move kernels between
/// devices, but never duplicates or loses one.
fn assert_conserved(rep: &MultiGpuReport, arrivals: usize, label: &str) {
    let incomplete: usize = rep.reports.iter().map(|r| r.incomplete).sum();
    assert_eq!(
        completed(rep)
            + rep.admission.total_shed()
            + rep.admission.total_deferred_unfinished()
            + rep.resilience.stranded
            + incomplete,
        arrivals,
        "{label}: kernels not conserved"
    );
    // Counted exactly once: fleet-wide completion ids are disjoint
    // across devices (a re-routed kernel completes on exactly one).
    let mut ids: Vec<u64> =
        rep.reports.iter().flat_map(|r| r.completion.keys().copied()).collect();
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "{label}: a kernel completed on two devices");
    assert_eq!(n, completed(rep), "{label}: completion log disagrees with counts");
}

/// DIFFERENTIAL (the tentpole's zero-cost pin): installing an empty
/// [`FaultPlan`] must leave every run bit-identical to the faultless
/// dispatcher — the `ScaledTiming` wrappers pass through untouched at
/// scale 1.0, the active list covers the whole fleet, and the
/// resilience ledger only observes. Checked on every scenario ×
/// {roundrobin, sloaware, efc} × preemption {off, on}.
#[test]
fn empty_fault_plan_is_bit_identical_on_all_scenarios() {
    let gpu = GpuConfig::c2050();
    let coord = Coordinator::new(&gpu);
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    for scenario in SCENARIO_NAMES {
        for policy in ["roundrobin", "sloaware", "efc"] {
            for preempt in [false, true] {
                let label = format!("{scenario}/{policy}/preempt={preempt}");
                let build = || {
                    let mut d = MultiGpuDispatcher::new(
                        &gpus,
                        DispatchSpec::from_name(policy).expect("valid policy").build(),
                    );
                    if preempt {
                        d = d.with_preemption(PreemptCost::for_gpu(&gpu));
                    }
                    d
                };
                let mk = || {
                    scenario_source(scenario, Mix::MIX, 4, 2.0 * capacity, SEED, qos)
                        .expect("valid scenario")
                };
                let plain = build().run_source(mk().as_mut());
                let pinned = build().with_faults(FaultPlan::new()).run_source(mk().as_mut());
                assert_eq!(
                    pinned.makespan_secs.to_bits(),
                    plain.makespan_secs.to_bits(),
                    "{label}: makespan"
                );
                assert_eq!(
                    pinned.throughput_kps.to_bits(),
                    plain.throughput_kps.to_bits(),
                    "{label}: throughput"
                );
                assert_eq!(
                    pinned.goodput_kps.to_bits(),
                    plain.goodput_kps.to_bits(),
                    "{label}: goodput"
                );
                assert_eq!(pinned.per_device, plain.per_device, "{label}: per-device");
                assert_eq!(pinned.eta, plain.eta, "{label}: eta calibration");
                assert_eq!(pinned.tenants, plain.tenants, "{label}: tenant rows");
                assert_eq!(pinned.shed_retries, plain.shed_retries, "{label}: retries");
                assert_eq!(
                    pinned.reports.len(),
                    plain.reports.len(),
                    "{label}: report count"
                );
                for (a, b) in pinned.reports.iter().zip(&plain.reports) {
                    assert_eq!(a.total_cycles, b.total_cycles, "{label}: total_cycles");
                    assert_eq!(a.completion, b.completion, "{label}: completion map");
                    assert_eq!(a.slice_trace, b.slice_trace, "{label}: slice trace");
                    assert_eq!(a.queue_depth, b.queue_depth, "{label}: queue depth");
                    assert_eq!(a.qos, b.qos, "{label}: per-class stats");
                    assert_eq!(a.preemptions, b.preemptions, "{label}: preemptions");
                    assert_eq!(a.incomplete, b.incomplete, "{label}: incomplete");
                }
                // The inert plan observed but changed nothing: no
                // events, nothing stranded, and the pre-fault phase is
                // the whole run.
                assert!(pinned.resilience.events.is_empty(), "{label}: events fired");
                assert_eq!(pinned.resilience.stranded, 0, "{label}: stranded");
                assert_eq!(pinned.resilience.scale_ups, 0, "{label}: scale-ups");
                assert!(
                    (pinned.resilience.goodput_pre_kps - pinned.goodput_kps).abs() < 1e-9,
                    "{label}: pre-fault goodput {} != run goodput {}",
                    pinned.resilience.goodput_pre_kps,
                    pinned.goodput_kps
                );
            }
        }
    }
}

/// PROPERTY: under seeded mixed churn (drains + slowdowns) the fleet
/// never loses or duplicates a kernel — with and without a router
/// admission gate, on both an oblivious and a calibrated router.
#[test]
fn seeded_churn_conserves_every_kernel() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let gpus = vec![GpuConfig::c2050(); 3];
    let per_app = 12;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    let rate = 1.5 * capacity * gpus.len() as f64;
    let span = arrivals as f64 / rate;
    for churn_seed in [1u64, 2, 3] {
        let plan = FaultPlan::seeded_churn(SEED ^ churn_seed, gpus.len(), 3, span);
        // Device 0 is the churn survivor, so the fleet always keeps a
        // route and nothing is ever stranded by these plans.
        for ev in plan.events() {
            if let FaultEvent::Drain { device, .. } = ev {
                assert_ne!(*device, 0, "churn drained the survivor: {ev:?}");
            }
        }
        for policy in [DispatchPolicy::RoundRobin, DispatchPolicy::EarliestFeasible] {
            for gated in [false, true] {
                let label = format!("churn{churn_seed}/{policy:?}/gated={gated}");
                let mut dispatcher = MultiGpuDispatcher::new(&gpus, policy)
                    .with_faults(plan.clone());
                if gated {
                    dispatcher = dispatcher
                        .with_admission(AdmissionSpec::BacklogCap { cap: 6 }, ShedPoint::Router);
                }
                let mut source =
                    scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 4, QosMix::ALL_BATCH)
                        .expect("valid scenario");
                let rep = dispatcher.run_source(source.as_mut());
                assert_conserved(&rep, arrivals, &label);
                // Event-level stranding sums to the fleet number (the
                // survivor guarantees no arrival-time stranding).
                let event_stranded: usize =
                    rep.resilience.events.iter().map(|e| e.stranded).sum();
                assert_eq!(rep.resilience.stranded, event_stranded, "{label}: stranded split");
                assert_eq!(rep.resilience.stranded, 0, "{label}: churn stranded work");
            }
        }
    }
}

/// The drain drill, happy path: losing one of two devices mid-run
/// re-routes its withdrawn pending set to the survivor and every
/// kernel still completes.
#[test]
fn drain_reroutes_pending_work_to_the_survivor() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    let per_app = 15;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    let rate = 2.0 * capacity * gpus.len() as f64;
    let span = arrivals as f64 / rate;
    // 2x overload guarantees a backlog on the drained device at onset.
    let plan = FaultPlan::new()
        .with_event(FaultEvent::Drain { at_secs: 0.4 * span, device: 1 });
    let dispatcher =
        MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin).with_faults(plan);
    let mut source =
        scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 5, QosMix::ALL_BATCH)
            .expect("valid scenario");
    let rep = dispatcher.run_source(source.as_mut());
    assert_eq!(rep.resilience.events.len(), 1);
    let ev = &rep.resilience.events[0];
    assert_eq!(ev.kind, "drain");
    assert_eq!(ev.device, 1);
    assert!(ev.rerouted >= 1, "drain withdrew nothing: {ev:?}");
    assert_eq!(ev.stranded, 0, "a survivor existed, nothing may strand");
    assert_eq!(rep.resilience.stranded, 0);
    assert!(
        rep.resilience.reroute_latency_mean_secs > 0.0,
        "re-routed kernels completed, so the re-route latency is positive"
    );
    // Everything completes: the withdrawn work landed on the survivor.
    assert_eq!(completed(&rep), arrivals, "re-routed kernels lost");
    assert_conserved(&rep, arrivals, "drain");
    assert!(
        rep.per_device[0].1 > rep.per_device[1].1,
        "the survivor absorbed the re-routes: {:?}",
        rep.per_device
    );
}

/// The drain drill, edge path: draining the *last* device strands its
/// withdrawn pending set and every later arrival — counted and
/// reported, never silently lost.
#[test]
fn draining_the_last_device_strands_instead_of_losing() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let gpus = vec![GpuConfig::c2050()];
    let per_app = 15;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    let rate = 2.0 * capacity;
    let span = arrivals as f64 / rate;
    let plan = FaultPlan::new()
        .with_event(FaultEvent::Drain { at_secs: 0.3 * span, device: 0 });
    let dispatcher =
        MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin).with_faults(plan);
    let mut source =
        scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 6, QosMix::ALL_BATCH)
            .expect("valid scenario");
    let rep = dispatcher.run_source(source.as_mut());
    let ev = &rep.resilience.events[0];
    assert_eq!(ev.kind, "drain");
    assert_eq!(ev.rerouted, 0, "no survivor can take re-routes: {ev:?}");
    assert!(rep.resilience.stranded > 0, "a fully drained fleet must strand");
    // The stranded count is the event's withdrawals plus the arrivals
    // that found no active device afterwards.
    assert!(rep.resilience.stranded >= ev.stranded, "{:?}", rep.resilience);
    assert!(completed(&rep) > 0, "the pre-drain phase completed work");
    assert_conserved(&rep, arrivals, "last-device drain");
}

/// The slowdown drill (the tentpole's calibration story): a 3× fault
/// on one of two `efc` devices is invisible to the routing-side price
/// model — only ETA calibration can notice it. The degraded device's
/// learned correction must grow past the healthy device's, its share
/// of routed kernels must drop versus the fault-free control, and the
/// calibrated router must beat the uncalibrated `SloAware` fleet on
/// the latency-class tail for the same seed and the same fault.
#[test]
fn slowdown_is_detected_by_calibration_and_routed_around() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    let per_app = 30;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    let rate = 1.5 * capacity * gpus.len() as f64;
    let span = arrivals as f64 / rate;
    let fault = FaultPlan::new().with_event(FaultEvent::Slowdown {
        at_secs: 0.3 * span,
        device: 1,
        factor: 3.0,
    });
    let run = |policy: DispatchPolicy, plan: FaultPlan| {
        let mut source =
            scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 7, qos)
                .expect("valid scenario");
        MultiGpuDispatcher::new(&gpus, policy).with_faults(plan).run_source(source.as_mut())
    };
    let faulted = run(DispatchPolicy::EarliestFeasible, fault.clone());
    let control = run(DispatchPolicy::EarliestFeasible, FaultPlan::new());
    let blind = run(DispatchPolicy::SloAware, fault);

    assert_eq!(faulted.resilience.events[0].kind, "slowdown");
    assert_conserved(&faulted, arrivals, "slowdown/efc");

    // Calibration noticed: the degraded device's correction grew past
    // the healthy device's AND past its own fault-free baseline.
    assert_eq!(faulted.eta.len(), 2, "efc reports per-device calibration");
    let (healthy, degraded) = (faulted.eta[0].correction, faulted.eta[1].correction);
    assert!(
        degraded > healthy,
        "calibration missed the slowdown: degraded {degraded} !> healthy {healthy}"
    );
    assert!(
        degraded > control.eta[1].correction,
        "correction did not grow over the fault-free baseline: {degraded} vs {}",
        control.eta[1].correction
    );

    // Routing followed the calibration: the degraded device's share of
    // routed kernels dropped versus the fault-free control.
    assert!(
        faulted.per_device[1].1 < control.per_device[1].1,
        "router kept feeding the degraded device: {:?} vs control {:?}",
        faulted.per_device,
        control.per_device
    );

    // And it paid off where the SLO lives: the calibrated router's
    // latency-class p99 beats the uncalibrated SloAware fleet that saw
    // the identical arrivals and the identical fault.
    let (p_efc, p_blind) = (
        faulted.fleet_qos().latency.p99_turnaround_secs,
        blind.fleet_qos().latency.p99_turnaround_secs,
    );
    assert!(
        p_efc < p_blind,
        "calibrated p99 {p_efc} !< uncalibrated p99 {p_blind}"
    );
}

/// The autoscaler's scale-up signal: sustained router shedding joins a
/// warm spare, which then serves real work.
#[test]
fn autoscaler_joins_a_spare_under_sustained_shedding() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    let per_app = 40;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    // 3x one device's capacity: the single active device must shed.
    let rate = 3.0 * capacity;
    let span = arrivals as f64 / rate;
    let plan = FaultPlan::new()
        .with_autoscaler(AutoscalerSpec::new(1, span / 30.0).with_shed_threshold(1));
    let dispatcher = MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin)
        .with_admission(AdmissionSpec::BacklogCap { cap: 4 }, ShedPoint::Router)
        .with_faults(plan);
    let mut source =
        scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 8, QosMix::ALL_BATCH)
            .expect("valid scenario");
    let rep = dispatcher.run_source(source.as_mut());
    assert!(rep.admission.total_shed() > 0, "craft broken: overload never shed");
    assert!(rep.resilience.scale_ups >= 1, "sustained shedding never scaled up");
    assert_eq!(rep.resilience.peak_active_devices, 2, "the spare never counted active");
    assert!(
        rep.resilience.events.iter().any(|e| e.kind == "scale-up"),
        "scale-up left no event record"
    );
    assert!(rep.per_device[1].1 > 0, "the joined spare served nothing");
    assert_conserved(&rep, arrivals, "autoscale-up");
}

/// The autoscaler's scale-down signal: a device idle at consecutive
/// checks retires from the active set (never below one device), and
/// the remaining device still completes everything.
#[test]
fn autoscaler_retires_an_idle_device() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    let per_app = 10;
    let arrivals = per_app as usize * Mix::MIX.apps().len();
    // Half of one device's capacity across two devices: both idle most
    // of the time, so an idle check is guaranteed early.
    let rate = 0.5 * capacity;
    let span = arrivals as f64 / rate;
    let plan = FaultPlan::new()
        .with_autoscaler(AutoscalerSpec::new(2, span / 80.0).with_idle_intervals(1));
    let dispatcher =
        MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin).with_faults(plan);
    let mut source =
        scenario_source("poisson", Mix::MIX, per_app, rate, SEED ^ 9, QosMix::ALL_BATCH)
            .expect("valid scenario");
    let rep = dispatcher.run_source(source.as_mut());
    // Without an admission gate nothing sheds, so the retired device
    // can never rejoin and exactly one scale-down is possible (the
    // floor of one active device blocks a second).
    assert_eq!(rep.resilience.scale_ups, 0, "no sheds, no scale-up signal");
    assert_eq!(rep.resilience.scale_downs, 1, "idle device never retired");
    assert_eq!(rep.resilience.final_active_devices, 1, "{:?}", rep.resilience);
    assert!(
        rep.resilience.events.iter().any(|e| e.kind == "scale-down"),
        "scale-down left no event record"
    );
    assert_eq!(completed(&rep), arrivals, "the surviving device dropped work");
    assert_conserved(&rep, arrivals, "autoscale-down");
}
