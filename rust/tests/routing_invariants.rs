//! ETA-routing and preemption invariants.
//!
//! The two guarantees this suite pins:
//!
//! 1. **Zero-urgency differential** — on all-batch, no-deadline
//!    workloads the new machinery is invisible: a preemption-enabled
//!    engine run is bit-identical to the frozen PR-4 paths (plain
//!    Kernelet and the preemption-free `DeadlineSelector`), and an
//!    `EarliestFeasible` fleet is bit-identical to the `RoundRobin`
//!    fleet (all-batch work rides the same wheel, and the per-device
//!    deadline selectors defer wholesale to Kernelet).
//! 2. **Conservation** — `EarliestFeasible` routing partitions arrivals
//!    exactly like the PR-4 invariant: every arrival is completed,
//!    shed or left deferred, fleet-wide and per class, with no kernel
//!    duplicated across devices — with and without an admission gate.

use std::collections::HashSet;

use kernelet::config::GpuConfig;
use kernelet::coordinator::{
    AdmissionSpec, Coordinator, DeadlineSelector, DispatchPolicy, Engine, KerneletSelector,
    MultiGpuDispatcher, PreemptCost, ShedPoint,
};
use kernelet::figures::throughput::base_capacity_kps;
use kernelet::workload::{scenario_source, Mix, QosMix};

const SEED: u64 = 0xE7C_0515;

/// DIFFERENTIAL: with nothing latency-class and nothing deadlined, the
/// preemption-enabled deadline selector schedules bit-identically to
/// plain Kernelet and to the preemption-free PR-4 selector on every
/// open-loop scenario — same completion map, slice trace, clock, and
/// zero preemptions.
#[test]
fn preemption_enabled_engine_is_bit_identical_on_zero_urgency_workloads() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    for scenario in ["poisson", "bursty", "diurnal", "heavytail"] {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 5, 2.0 * capacity, SEED, QosMix::ALL_BATCH)
                .expect("valid scenario")
        };
        let frozen = Engine::new(&coord).run_source(&mut KerneletSelector, mk().as_mut());
        let pr4 = Engine::new(&coord)
            .run_source(&mut DeadlineSelector::new(), mk().as_mut());
        let preempting = Engine::new(&coord).run_source(
            &mut DeadlineSelector::new().with_preemption(PreemptCost::for_gpu(&coord.gpu)),
            mk().as_mut(),
        );
        for (name, rep) in [("pr4-deadline", &pr4), ("preempting", &preempting)] {
            assert_eq!(rep.total_cycles, frozen.total_cycles, "{scenario}/{name}: clock");
            assert_eq!(rep.completion, frozen.completion, "{scenario}/{name}: completions");
            assert_eq!(rep.slice_trace, frozen.slice_trace, "{scenario}/{name}: slice trace");
            assert_eq!(rep.queue_depth, frozen.queue_depth, "{scenario}/{name}: queue depth");
            assert_eq!(
                rep.coschedule_rounds, frozen.coschedule_rounds,
                "{scenario}/{name}: rounds"
            );
            assert_eq!(rep.preemptions, 0, "{scenario}/{name}: phantom preemption");
        }
    }
}

/// DIFFERENTIAL: an `EarliestFeasible` fleet on an all-batch workload
/// is bit-identical to the frozen `RoundRobin` fleet — batch work rides
/// the same wheel, ETA models never decide anything, and the
/// preemption-enabled per-device selectors defer wholesale to Kernelet.
#[test]
fn efc_fleet_is_bit_identical_to_round_robin_on_all_batch() {
    let gpus = [GpuConfig::c2050(), GpuConfig::gtx680()];
    let capacity = base_capacity_kps(&Coordinator::new(&gpus[0]), Mix::MIX);
    for scenario in ["poisson", "bursty", "heavytail"] {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 5, 2.5 * capacity, SEED ^ 3, QosMix::ALL_BATCH)
                .expect("valid scenario")
        };
        let rr = MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin)
            .run_source(mk().as_mut());
        let efc = MultiGpuDispatcher::new(&gpus, DispatchPolicy::EarliestFeasible)
            .run_source(mk().as_mut());
        assert_eq!(efc.makespan_secs, rr.makespan_secs, "{scenario}: makespan");
        assert_eq!(efc.per_device, rr.per_device, "{scenario}: routing");
        for (i, (a, b)) in efc.reports.iter().zip(&rr.reports).enumerate() {
            assert_eq!(a.total_cycles, b.total_cycles, "{scenario}: device {i} clock");
            assert_eq!(a.completion, b.completion, "{scenario}: device {i} completions");
            assert_eq!(a.slice_trace, b.slice_trace, "{scenario}: device {i} trace");
            assert_eq!(a.preemptions, 0, "{scenario}: device {i} phantom preemption");
        }
    }
}

/// PROPERTY: `EarliestFeasible` conserves arrivals exactly like the
/// PR-4 partition invariant — across scenarios, every arrival is
/// completed (or accounted shed/deferred under a router gate), no id
/// lands on two devices, and the fleet QoS merge covers every
/// completion once.
#[test]
fn efc_routing_conserves_arrivals_across_scenarios() {
    let gpus = [GpuConfig::c2050(), GpuConfig::c2050(), GpuConfig::gtx680()];
    let capacity = base_capacity_kps(&Coordinator::new(&gpus[0]), Mix::MIX);
    let qos = QosMix::latency_share(0.4, 4.0 / capacity);
    for scenario in ["poisson", "bursty", "diurnal", "heavytail", "closed"] {
        let mut src =
            scenario_source(scenario, Mix::MIX, 6, 2.0 * capacity * 3.0, SEED ^ 9, qos)
                .expect("valid scenario");
        let d = MultiGpuDispatcher::new(&gpus, DispatchPolicy::EarliestFeasible);
        let rep = d.run_source(src.as_mut());
        let routed: usize = rep.per_device.iter().map(|p| p.1).sum();
        assert_eq!(routed, 24, "{scenario}: routed != arrivals");
        assert!(rep.reports.iter().all(|r| r.incomplete == 0), "{scenario}");
        let mut ids: Vec<u64> =
            rep.reports.iter().flat_map(|r| r.completion.keys().copied()).collect();
        ids.sort_unstable();
        let unique: HashSet<u64> = ids.iter().copied().collect();
        assert_eq!(unique.len(), ids.len(), "{scenario}: kernel ran on two devices");
        assert_eq!(ids.len(), 24, "{scenario}: completions != arrivals");
        let fleet = rep.fleet_qos();
        assert_eq!(fleet.latency.completed + fleet.batch.completed, 24, "{scenario}");
        // ETA stats exist per device and jointly cover the fleet.
        assert_eq!(rep.eta.len(), gpus.len(), "{scenario}");
        assert_eq!(
            rep.eta.iter().map(|e| e.samples).sum::<usize>(),
            24,
            "{scenario}: unscored completions"
        );
    }
}

/// PROPERTY: the partition survives an admission gate at the router —
/// completed + shed + deferred-unfinished == arrivals under
/// `EarliestFeasible`, exactly as PR-4 pinned it for the other
/// policies.
#[test]
fn efc_routing_conserves_under_router_admission() {
    let gpus = [GpuConfig::c2050(), GpuConfig::c2050()];
    let capacity = base_capacity_kps(&Coordinator::new(&gpus[0]), Mix::MIX);
    let qos = QosMix::latency_share(0.25, 4.0 / capacity);
    for spec in [
        AdmissionSpec::BacklogCap { cap: 3 },
        AdmissionSpec::for_policy("sloguard", capacity, 4.0, 8),
    ] {
        for point in [ShedPoint::Router, ShedPoint::Device] {
            let d = MultiGpuDispatcher::new(&gpus, DispatchPolicy::EarliestFeasible)
                .with_admission(spec, point);
            let mut src =
                scenario_source("bursty", Mix::MIX, 10, 6.0 * capacity, SEED ^ 77, qos)
                    .expect("valid scenario");
            let rep = d.run_source(src.as_mut());
            let a = &rep.admission;
            assert_eq!(a.total_arrivals(), 40, "{spec:?}/{point:?}");
            let completed: usize = rep.reports.iter().map(|r| r.kernels_completed).sum();
            assert_eq!(
                completed + a.total_shed() + a.total_deferred_unfinished(),
                40,
                "{spec:?}/{point:?}: partition broken"
            );
            assert!(rep.goodput_kps <= rep.throughput_kps + 1e-9, "{spec:?}/{point:?}");
        }
    }
}

/// The headline property at fleet level (softer than the bench bar, on
/// a fixed seed): under bursty overload with a latency/batch mix, EFC
/// routing + preemption does not lose to SloAware on fleet
/// latency-class deadline misses.
#[test]
fn efc_not_worse_than_sloaware_on_fleet_misses() {
    let gpus = [GpuConfig::c2050(), GpuConfig::c2050()];
    let capacity = base_capacity_kps(&Coordinator::new(&gpus[0]), Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    let offered = 3.0 * capacity * 2.0;
    let mk = || {
        scenario_source("bursty", Mix::MIX, 25, offered, SEED ^ 21, qos).expect("valid scenario")
    };
    let slo = MultiGpuDispatcher::new(&gpus, DispatchPolicy::SloAware)
        .run_source(mk().as_mut())
        .fleet_qos();
    let efc = MultiGpuDispatcher::new(&gpus, DispatchPolicy::EarliestFeasible)
        .run_source(mk().as_mut())
        .fleet_qos();
    assert!(
        efc.latency.deadline_misses <= slo.latency.deadline_misses,
        "efc misses {} > sloaware misses {}",
        efc.latency.deadline_misses,
        slo.latency.deadline_misses
    );
}
