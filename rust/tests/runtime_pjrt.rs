//! Integration tests over the real PJRT path: load AOT artifacts,
//! execute sliced, verify against the full-grid run, and check the
//! markov artifact against the native model solver.
//!
//! These tests skip (pass vacuously, with a note) when `make artifacts`
//! has not run — cargo test must stay green from a bare checkout — and
//! the whole file compiles away without the `pjrt` cargo feature (the
//! xla binding needs the native XLA extension library).
#![cfg(feature = "pjrt")]

use kernelet::model::chain::Transition;
use kernelet::runtime::{artifacts_available, ArtifactRegistry, SlicedRunner};

fn registry() -> Option<ArtifactRegistry> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactRegistry::open_default().expect("open registry"))
}

#[test]
fn platform_is_cpu() {
    let Some(reg) = registry() else { return };
    assert!(reg.platform().to_lowercase().contains("host") || reg.platform().to_lowercase().contains("cpu"));
}

#[test]
fn manifest_lists_all_eight_kernels() {
    let Some(reg) = registry() else { return };
    let names = reg.manifest().kernels();
    for k in ["bs", "mm", "mriq", "pc", "sad", "spmv", "st", "tea"] {
        assert!(names.iter().any(|n| n == k), "missing {k} in {names:?}");
    }
}

#[test]
fn every_kernel_sliced_equals_full() {
    let Some(reg) = registry() else { return };
    let runner = SlicedRunner::new(&reg);
    for kernel in reg.manifest().kernels() {
        let inputs = runner.example_inputs(&kernel, 42).expect("inputs");
        // Partitions exercising every AOT variant: 8 = 4+4 = 4+2+2.
        for slices in [vec![8u32], vec![4, 4], vec![4, 2, 2], vec![2, 2, 2, 2]] {
            runner
                .run_verified(&kernel, &inputs, &slices)
                .unwrap_or_else(|e| panic!("{kernel} {slices:?}: {e}"));
        }
    }
}

#[test]
fn slice_offsets_select_distinct_regions() {
    let Some(reg) = registry() else { return };
    let runner = SlicedRunner::new(&reg);
    let inputs = runner.example_inputs("mm", 7).unwrap();
    let full = runner.run_full("mm", &inputs).unwrap();
    let half1 = runner.run_sliced("mm", &inputs, &[4, 4]).unwrap();
    assert_eq!(full, half1);
}

#[test]
fn markov_artifact_agrees_with_native_solver() {
    let Some(reg) = registry() else { return };
    // A random ergodic 12-state chain.
    let n = 12;
    let mut rng = kernelet::stats::Xoshiro256::new(2024);
    let mut p = vec![vec![0f64; n]; n];
    for row in p.iter_mut() {
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = rng.f64() + 0.02;
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
    // Native power iteration.
    let mut t = Transition::new(n);
    for i in 0..n {
        t.row_mut(i).copy_from_slice(&p[i]);
    }
    let native = kernelet::model::steady_state_power(&t, 1e-12, 100_000);
    // PJRT artifact.
    let pjrt = kernelet::runtime::dispatch::steady_state_pjrt(&reg, &p).expect("pjrt steady");
    for (a, b) in native.iter().zip(&pjrt) {
        assert!((a - b).abs() < 5e-4, "native={a} pjrt={b}");
    }
}

#[test]
fn executables_are_cached() {
    let Some(reg) = registry() else { return };
    let runner = SlicedRunner::new(&reg);
    let inputs = runner.example_inputs("sad", 1).unwrap();
    runner.run_sliced("sad", &inputs, &[4, 4]).unwrap();
    let after_first = reg.compiled_count();
    runner.run_sliced("sad", &inputs, &[4, 4]).unwrap();
    assert_eq!(reg.compiled_count(), after_first, "recompiled on second run");
}
