//! Property-based invariant tests over the scheduling stack.
//!
//! proptest is unavailable offline, so properties are checked over
//! randomized cases drawn from the library's own deterministic RNG —
//! every failure is reproducible from the printed seed.

use kernelet::config::GpuConfig;
use kernelet::coordinator::baselines::{run_base, run_opt};
use kernelet::coordinator::{coresident_feasible, feasible_splits, run_kernelet, Coordinator};
use kernelet::kernel::{BenchmarkApp, InstructionMix, KernelInstance, KernelSpec};
use kernelet::model::chain::{steady_state_dense, steady_state_power};
use kernelet::model::homo::build_homo_chain;
use kernelet::model::params::{ChainParams, Granularity, SmEnv};
use kernelet::stats::Xoshiro256;
use kernelet::workload::{Mix, Stream};

fn random_spec(rng: &mut Xoshiro256, id: u32) -> KernelSpec {
    let threads = *rng.choose(&[32u32, 64, 128, 256, 512]);
    KernelSpec {
        name: Box::leak(format!("RND{id}").into_boxed_str()),
        grid_blocks: 28 + rng.below(200) as u32,
        threads_per_block: threads,
        regs_per_thread: 16 + rng.below(32) as u32,
        smem_per_block: *rng.choose(&[0u32, 4096, 8192, 16384]),
        inst_per_warp: 64 + rng.below(2048) as u32,
        mix: InstructionMix {
            mem_ratio: rng.range_f64(0.0, 0.5),
            uncoalesced_frac: if rng.chance(0.3) { rng.f64() } else { 0.0 },
            uncoalesced_fanout: 1 + rng.below(31) as u32,
        },
        arith_latency: 10 + rng.below(40) as u32,
        ilp: rng.range_f64(0.4, 2.5),
    }
}

/// PROPERTY: every policy executes every thread block of every kernel
/// exactly once — total instructions are conserved, kernels all finish.
#[test]
fn work_conservation_across_policies() {
    for seed in [1u64, 7, 42] {
        let coord = Coordinator::new(&GpuConfig::c2050());
        let stream = Stream::saturated(Mix::MIX, 3, seed);
        for (name, rep) in [
            ("base", run_base(&coord, &stream)),
            ("kernelet", run_kernelet(&coord, &stream)),
            ("opt", run_opt(&coord, &stream)),
        ] {
            assert_eq!(rep.kernels_completed, stream.len(), "{name} seed={seed}");
            // Every instance has a completion time after its arrival.
            for k in &stream.instances {
                let done = rep.completion.get(&k.id).unwrap_or_else(|| {
                    panic!("{name} seed={seed}: kernel {} never completed", k.id)
                });
                assert!(*done >= k.arrival_time, "{name} seed={seed}");
            }
        }
    }
}

/// PROPERTY: schedules are deterministic given the stream.
#[test]
fn scheduling_deterministic() {
    let coord = Coordinator::new(&GpuConfig::gtx680());
    let stream = Stream::saturated(Mix::ALL, 2, 99);
    let a = run_kernelet(&coord, &stream);
    let b = run_kernelet(&coord, &stream);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.coschedule_rounds, b.coschedule_rounds);
}

/// PROPERTY: OPT (oracle pre-execution) never loses to Kernelet by more
/// than launch-overhead noise, and both never lose to BASE by more than
/// noise (the greedy fallback runs solo == BASE when nothing profits).
#[test]
fn policy_ordering() {
    for (gpu, seed) in [(GpuConfig::c2050(), 5u64), (GpuConfig::gtx680(), 6)] {
        let coord = Coordinator::new(&gpu);
        let stream = Stream::saturated(Mix::ALL, 4, seed);
        let base = run_base(&coord, &stream).total_secs;
        let ours = run_kernelet(&coord, &stream).total_secs;
        let opt = run_opt(&coord, &stream).total_secs;
        assert!(opt <= ours * 1.05, "{}: opt={opt} kernelet={ours}", gpu.name);
        assert!(ours <= base * 1.05, "{}: kernelet={ours} base={base}", gpu.name);
    }
}

/// PROPERTY: feasible splits are exactly the co-resident-feasible grid
/// points, for random kernel pairs.
#[test]
fn split_enumeration_sound_and_complete() {
    let mut rng = Xoshiro256::new(0xFEA51B1E);
    let gpu = GpuConfig::c2050();
    for case in 0..20 {
        let a = random_spec(&mut rng, case * 2);
        let b = random_spec(&mut rng, case * 2 + 1);
        let splits = feasible_splits(&gpu, &a, &b);
        for &(b1, b2) in &splits {
            assert!(coresident_feasible(&gpu, &a, b1, &b, b2), "case {case}");
        }
        // Completeness over the quota grid.
        let mut count = 0;
        for b1 in 1..=a.blocks_per_sm(&gpu) {
            for b2 in 1..=b.blocks_per_sm(&gpu) {
                if coresident_feasible(&gpu, &a, b1, &b, b2) {
                    count += 1;
                }
            }
        }
        assert_eq!(count, splits.len(), "case {case}");
    }
}

/// PROPERTY: for random kernels the homogeneous chain is stochastic and
/// its two steady-state solvers agree.
#[test]
fn chain_invariants_random_kernels() {
    let mut rng = Xoshiro256::new(0xC4A1A);
    let gpu = GpuConfig::c2050();
    let env = SmEnv::virtual_sm(&gpu);
    for case in 0..25 {
        let spec = random_spec(&mut rng, 1000 + case);
        let blocks = spec.blocks_per_sm(&gpu);
        let p = ChainParams::from_kernel(&gpu, &spec, blocks, Granularity::Block, env.vsm_count);
        let chain = build_homo_chain(&p, &env);
        chain.validate(1e-8);
        let a = steady_state_power(&chain, 1e-12, 50_000);
        let b = steady_state_dense(&chain);
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-8, "case {case}: sum={sum}");
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "case {case}: power={x} dense={y}");
        }
    }
}

/// PROPERTY: simulator work accounting is exact for random kernels.
#[test]
fn simulator_work_accounting_random() {
    let mut rng = Xoshiro256::new(0x51111);
    let gpu = GpuConfig::gtx680();
    for case in 0..15 {
        let spec = random_spec(&mut rng, 2000 + case);
        let r = kernelet::sim::simulate_solo(&gpu, &spec, case as u64);
        let blocks = kernelet::sim::blocks_on_sm(&gpu, spec.grid_blocks);
        assert_eq!(r.kernels[0].blocks_completed, blocks, "case {case}");
        assert_eq!(
            r.kernels[0].insts,
            blocks as u64 * spec.inst_per_block(&gpu),
            "case {case}"
        );
        assert!(r.ipc(&gpu) <= gpu.peak_ipc() + 1e-9, "case {case}: ipc={}", r.ipc(&gpu));
    }
}

/// PROPERTY: co-run of a pair conserves both kernels' work and neither
/// kernel's cIPC exceeds the GPU peak.
#[test]
fn pair_simulation_invariants_random() {
    let mut rng = Xoshiro256::new(0xAB2E11);
    let gpu = GpuConfig::c2050();
    for case in 0..10 {
        let a = random_spec(&mut rng, 3000 + case);
        let b = random_spec(&mut rng, 3100 + case);
        let splits = feasible_splits(&gpu, &a, &b);
        if splits.is_empty() {
            continue;
        }
        let &(q1, q2) = rng.choose(&splits);
        let (s1, s2) = (q1 * gpu.num_sms, q2 * gpu.num_sms);
        let pr = kernelet::sim::simulate_pair(&gpu, &a, s1, q1, &b, s2, q2, case as u64);
        let b1 = kernelet::sim::blocks_on_sm(&gpu, s1);
        let b2 = kernelet::sim::blocks_on_sm(&gpu, s2);
        assert_eq!(pr.per_kernel[0].insts, b1 as u64 * a.inst_per_block(&gpu));
        assert_eq!(pr.per_kernel[1].insts, b2 as u64 * b.inst_per_block(&gpu));
        assert!(pr.total_ipc() <= gpu.peak_ipc() + 1e-9);
    }
}

/// PROPERTY: take_slice covers each kernel's grid exactly once for
/// arbitrary slice-size sequences.
#[test]
fn slicing_partitions_grid() {
    let mut rng = Xoshiro256::new(0x5111CE);
    for case in 0..50 {
        let spec = BenchmarkApp::ALL[case % 8].spec().with_grid(97 + (case as u32 * 13) % 300);
        let mut inst = KernelInstance::new(case as u64, spec.clone(), 0.0);
        let mut seen = vec![false; spec.grid_blocks as usize];
        while !inst.is_finished() {
            let size = 1 + rng.below(60) as u32;
            for blk in inst.take_slice(size) {
                assert!(!seen[blk as usize], "case {case}: block {blk} twice");
                seen[blk as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: blocks missed");
    }
}
