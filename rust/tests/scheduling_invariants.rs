//! Property-based invariant tests over the scheduling stack.
//!
//! proptest is unavailable offline, so properties are checked over
//! randomized cases drawn from the library's own deterministic RNG —
//! every failure is reproducible from the printed seed.

use kernelet::config::GpuConfig;
use kernelet::coordinator::baselines::{run_base, run_monte_carlo, run_opt};
use kernelet::coordinator::{
    coresident_feasible, feasible_splits, run_kernelet, AdmissionSpec, Coordinator,
    DeadlineSelector, Engine, EngineBuilder, FifoSelector, KerneletSelector,
};
use kernelet::kernel::{BenchmarkApp, InstructionMix, KernelInstance, KernelSpec, Qos};
use kernelet::workload::ReplaySource;
use kernelet::model::chain::{steady_state_dense, steady_state_power};
use kernelet::model::homo::build_homo_chain;
use kernelet::model::params::{ChainParams, Granularity, SmEnv};
use kernelet::stats::Xoshiro256;
use kernelet::workload::{Mix, Stream};

fn random_spec(rng: &mut Xoshiro256, id: u32) -> KernelSpec {
    let threads = *rng.choose(&[32u32, 64, 128, 256, 512]);
    KernelSpec {
        name: Box::leak(format!("RND{id}").into_boxed_str()),
        grid_blocks: 28 + rng.below(200) as u32,
        threads_per_block: threads,
        regs_per_thread: 16 + rng.below(32) as u32,
        smem_per_block: *rng.choose(&[0u32, 4096, 8192, 16384]),
        inst_per_warp: 64 + rng.below(2048) as u32,
        mix: InstructionMix {
            mem_ratio: rng.range_f64(0.0, 0.5),
            uncoalesced_frac: if rng.chance(0.3) { rng.f64() } else { 0.0 },
            uncoalesced_fanout: 1 + rng.below(31) as u32,
        },
        arith_latency: 10 + rng.below(40) as u32,
        ilp: rng.range_f64(0.4, 2.5),
    }
}

/// PROPERTY: every policy executes every thread block of every kernel
/// exactly once — total instructions are conserved, kernels all finish.
#[test]
fn work_conservation_across_policies() {
    for seed in [1u64, 7, 42] {
        let coord = Coordinator::new(&GpuConfig::c2050());
        let stream = Stream::saturated(Mix::MIX, 3, seed);
        for (name, rep) in [
            ("base", run_base(&coord, &stream)),
            ("kernelet", run_kernelet(&coord, &stream)),
            ("opt", run_opt(&coord, &stream)),
        ] {
            assert_eq!(rep.kernels_completed, stream.len(), "{name} seed={seed}");
            // Every instance has a completion time after its arrival.
            for k in &stream.instances {
                let done = rep.completion.get(&k.id).unwrap_or_else(|| {
                    panic!("{name} seed={seed}: kernel {} never completed", k.id)
                });
                assert!(*done >= k.arrival_time, "{name} seed={seed}");
            }
        }
    }
}

/// PROPERTY: schedules are deterministic given the stream — the whole
/// report, not just the headline numbers: completion map, slice trace
/// and queue-depth timeline must be identical across runs.
#[test]
fn scheduling_deterministic() {
    let coord = Coordinator::new(&GpuConfig::gtx680());
    let stream = Stream::saturated(Mix::ALL, 2, 99);
    let a = run_kernelet(&coord, &stream);
    let b = run_kernelet(&coord, &stream);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.coschedule_rounds, b.coschedule_rounds);
    assert_eq!(a.completion, b.completion);
    assert_eq!(a.slice_trace, b.slice_trace);
    assert_eq!(a.queue_depth, b.queue_depth);
    assert_eq!(a.utilization, b.utilization);
    // MC is deterministic given (stream, seed) too.
    let small = Stream::saturated(Mix::MIX, 1, 4);
    assert_eq!(
        run_monte_carlo(&coord, &small, 3, 1234),
        run_monte_carlo(&coord, &small, 3, 1234)
    );
}

/// PROPERTY: the engine's enriched report is internally consistent —
/// utilization bounded, every grid block dispatched exactly once in the
/// slice trace, nothing incomplete.
#[test]
fn engine_report_consistent() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    for stream in [Stream::saturated(Mix::ALL, 2, 21), Stream::poisson(Mix::MIX, 3, 100.0, 22)] {
        for rep in [run_base(&coord, &stream), run_kernelet(&coord, &stream)] {
            assert_eq!(rep.incomplete, 0);
            assert!(rep.utilization > 0.0 && rep.utilization <= 1.0 + 1e-9, "{}", rep.utilization);
            assert!(rep.peak_queue_depth() <= stream.len());
            let dispatched = rep.blocks_dispatched();
            for k in &stream.instances {
                assert_eq!(
                    dispatched.get(&k.id).copied().unwrap_or(0),
                    k.spec.grid_blocks as u64,
                    "kernel {}",
                    k.id
                );
            }
        }
    }
}

/// PROPERTY: OPT (oracle pre-execution) never loses to Kernelet by more
/// than launch-overhead noise, and both never lose to BASE by more than
/// noise (the greedy fallback runs solo == BASE when nothing profits).
#[test]
fn policy_ordering() {
    for (gpu, seed) in [(GpuConfig::c2050(), 5u64), (GpuConfig::gtx680(), 6)] {
        let coord = Coordinator::new(&gpu);
        let stream = Stream::saturated(Mix::ALL, 4, seed);
        let base = run_base(&coord, &stream).total_secs;
        let ours = run_kernelet(&coord, &stream).total_secs;
        let opt = run_opt(&coord, &stream).total_secs;
        assert!(opt <= ours * 1.05, "{}: opt={opt} kernelet={ours}", gpu.name);
        assert!(ours <= base * 1.05, "{}: kernelet={ours} base={base}", gpu.name);
    }
}

/// PROPERTY: feasible splits are exactly the co-resident-feasible grid
/// points, for random kernel pairs.
#[test]
fn split_enumeration_sound_and_complete() {
    let mut rng = Xoshiro256::new(0xFEA51B1E);
    let gpu = GpuConfig::c2050();
    for case in 0..20 {
        let a = random_spec(&mut rng, case * 2);
        let b = random_spec(&mut rng, case * 2 + 1);
        let splits = feasible_splits(&gpu, &a, &b);
        for &(b1, b2) in &splits {
            assert!(coresident_feasible(&gpu, &a, b1, &b, b2), "case {case}");
        }
        // Completeness over the quota grid.
        let mut count = 0;
        for b1 in 1..=a.blocks_per_sm(&gpu) {
            for b2 in 1..=b.blocks_per_sm(&gpu) {
                if coresident_feasible(&gpu, &a, b1, &b, b2) {
                    count += 1;
                }
            }
        }
        assert_eq!(count, splits.len(), "case {case}");
    }
}

/// PROPERTY: for random kernels the homogeneous chain is stochastic and
/// its two steady-state solvers agree.
#[test]
fn chain_invariants_random_kernels() {
    let mut rng = Xoshiro256::new(0xC4A1A);
    let gpu = GpuConfig::c2050();
    let env = SmEnv::virtual_sm(&gpu);
    for case in 0..25 {
        let spec = random_spec(&mut rng, 1000 + case);
        let blocks = spec.blocks_per_sm(&gpu);
        let p = ChainParams::from_kernel(&gpu, &spec, blocks, Granularity::Block, env.vsm_count);
        let chain = build_homo_chain(&p, &env);
        chain.validate(1e-8);
        let a = steady_state_power(&chain, 1e-12, 50_000);
        let b = steady_state_dense(&chain);
        let sum: f64 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-8, "case {case}: sum={sum}");
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "case {case}: power={x} dense={y}");
        }
    }
}

/// PROPERTY: simulator work accounting is exact for random kernels.
#[test]
fn simulator_work_accounting_random() {
    let mut rng = Xoshiro256::new(0x51111);
    let gpu = GpuConfig::gtx680();
    for case in 0..15 {
        let spec = random_spec(&mut rng, 2000 + case);
        let r = kernelet::sim::simulate_solo(&gpu, &spec, case as u64);
        let blocks = kernelet::sim::blocks_on_sm(&gpu, spec.grid_blocks);
        assert_eq!(r.kernels[0].blocks_completed, blocks, "case {case}");
        assert_eq!(
            r.kernels[0].insts,
            blocks as u64 * spec.inst_per_block(&gpu),
            "case {case}"
        );
        assert!(r.ipc(&gpu) <= gpu.peak_ipc() + 1e-9, "case {case}: ipc={}", r.ipc(&gpu));
    }
}

/// PROPERTY: co-run of a pair conserves both kernels' work and neither
/// kernel's cIPC exceeds the GPU peak.
#[test]
fn pair_simulation_invariants_random() {
    let mut rng = Xoshiro256::new(0xAB2E11);
    let gpu = GpuConfig::c2050();
    for case in 0..10 {
        let a = random_spec(&mut rng, 3000 + case);
        let b = random_spec(&mut rng, 3100 + case);
        let splits = feasible_splits(&gpu, &a, &b);
        if splits.is_empty() {
            continue;
        }
        let &(q1, q2) = rng.choose(&splits);
        let (s1, s2) = (q1 * gpu.num_sms, q2 * gpu.num_sms);
        let pr = kernelet::sim::simulate_pair(&gpu, &a, s1, q1, &b, s2, q2, case as u64);
        let b1 = kernelet::sim::blocks_on_sm(&gpu, s1);
        let b2 = kernelet::sim::blocks_on_sm(&gpu, s2);
        assert_eq!(pr.per_kernel[0].insts, b1 as u64 * a.inst_per_block(&gpu));
        assert_eq!(pr.per_kernel[1].insts, b2 as u64 * b.inst_per_block(&gpu));
        assert!(pr.total_ipc() <= gpu.peak_ipc() + 1e-9);
    }
}

/// Frozen copies of the seed's four bespoke dispatch loops, kept
/// verbatim (modulo visibility plumbing) as the differential oracle:
/// the unified engine's adapters must reproduce their schedules
/// bit-for-bit on fixed streams. Do not "improve" this module — its
/// value is that it never changes with the engine.
mod reference {
    use std::collections::HashMap;

    use kernelet::coordinator::{feasible_splits, Coordinator};
    use kernelet::kernel::{KernelInstance, KernelSpec};
    use kernelet::stats::Xoshiro256;
    use kernelet::workload::Stream;

    /// Frozen copy of `stats::rng::split_seed` (splitmix64 finalizer
    /// over the (seed, index) pair). Deliberately NOT the production
    /// helper: if that helper regresses, the MC differential below must
    /// catch it rather than change in lockstep.
    fn ref_split_seed(seed: u64, index: u64) -> u64 {
        let mut z = seed ^ index.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub struct RefReport {
        pub total_cycles: f64,
        pub completion: HashMap<u64, f64>,
        pub rounds: u64,
        pub solo_slices: u64,
    }

    pub fn run_kernelet(coord: &Coordinator, stream: &Stream) -> RefReport {
        let gpu = coord.gpu.clone();
        let mut queue: Vec<KernelInstance> = Vec::new();
        let mut upcoming = stream.instances.clone();
        upcoming.reverse(); // pop() yields earliest arrival
        let mut clock_cycles = 0.0f64;
        let mut completion = HashMap::new();
        let mut rounds = 0u64;
        let mut solo_slices = 0u64;
        let secs = |c: f64| gpu.cycles_to_secs(c);

        loop {
            while upcoming.last().map_or(false, |k| k.arrival_time <= secs(clock_cycles)) {
                queue.push(upcoming.pop().unwrap());
            }
            if queue.is_empty() {
                match upcoming.last() {
                    Some(k) => {
                        clock_cycles = k.arrival_time * gpu.clock_hz();
                        continue;
                    }
                    None => break,
                }
            }
            let refs: Vec<&KernelInstance> = queue.iter().collect();
            match coord.find_coschedule(&refs) {
                Some(cs) => {
                    let i1 = queue.iter().position(|k| k.id == cs.k1).unwrap();
                    let i2 = queue.iter().position(|k| k.id == cs.k2).unwrap();
                    loop {
                        let (r1, r2) = {
                            let (lo, hi) = if i1 < i2 { (i1, i2) } else { (i2, i1) };
                            let (a, b) = queue.split_at_mut(hi);
                            let (ka, kb) = (&mut a[lo], &mut b[0]);
                            let (k1, k2) = if i1 < i2 { (ka, kb) } else { (kb, ka) };
                            let r1 = k1.take_slice(cs.size1.min(k1.remaining_blocks().max(1)));
                            let r2 = k2.take_slice(cs.size2.min(k2.remaining_blocks().max(1)));
                            (r1, r2)
                        };
                        let n1 = r1.end - r1.start;
                        let n2 = r2.end - r2.start;
                        let spec1 = queue[i1].spec.clone();
                        let spec2 = queue[i2].spec.clone();
                        let m = coord.simcache.pair(&spec1, n1, cs.b1, &spec2, n2, cs.b2);
                        clock_cycles += m.cycles;
                        rounds += 1;
                        let t = secs(clock_cycles);
                        if queue[i1].is_finished() {
                            completion.insert(queue[i1].id, t);
                        }
                        if queue[i2].is_finished() {
                            completion.insert(queue[i2].id, t);
                        }
                        let drained = queue[i1].is_finished() || queue[i2].is_finished();
                        let arrival = upcoming.last().map_or(false, |k| k.arrival_time <= t);
                        if drained || arrival {
                            break;
                        }
                    }
                    queue.retain(|k| !k.is_finished());
                }
                None => {
                    solo_step(
                        coord,
                        &mut queue,
                        &upcoming,
                        &mut clock_cycles,
                        &mut solo_slices,
                        &mut completion,
                    );
                }
            }
        }
        RefReport { total_cycles: clock_cycles, completion, rounds, solo_slices }
    }

    pub fn run_base(coord: &Coordinator, stream: &Stream) -> RefReport {
        let gpu = coord.gpu.clone();
        let mut clock_cycles = 0.0f64;
        let mut completion = HashMap::new();
        for k in &stream.instances {
            let arrival_cycles = k.arrival_time * gpu.clock_hz();
            if arrival_cycles > clock_cycles {
                clock_cycles = arrival_cycles;
            }
            clock_cycles += coord.simcache.solo_full(&k.spec);
            completion.insert(k.id, gpu.cycles_to_secs(clock_cycles));
        }
        RefReport {
            total_cycles: clock_cycles,
            completion,
            rounds: 0,
            solo_slices: stream.len() as u64,
        }
    }

    pub fn run_opt(coord: &Coordinator, stream: &Stream) -> RefReport {
        run_with_selector(coord, stream, &mut |coord, pending| select_opt(coord, pending))
    }

    pub fn run_monte_carlo(coord: &Coordinator, stream: &Stream, s: u32, seed: u64) -> Vec<f64> {
        (0..s)
            .map(|i| {
                let mut rng = Xoshiro256::new(ref_split_seed(seed, i as u64));
                let r = run_with_selector(coord, stream, &mut |coord, pending| {
                    select_random(coord, pending, &mut rng)
                });
                coord.gpu.cycles_to_secs(r.total_cycles)
            })
            .collect()
    }

    struct Decision {
        k1: u64,
        k2: u64,
        b1: u32,
        b2: u32,
        size1: u32,
        size2: u32,
    }

    fn select_opt(coord: &Coordinator, pending: &[&KernelInstance]) -> Option<Decision> {
        let mut apps: Vec<&KernelInstance> = Vec::new();
        for inst in pending {
            if !apps.iter().any(|k| k.spec.name == inst.spec.name) {
                apps.push(inst);
            }
        }
        if apps.len() < 2 {
            return None;
        }
        let mut best: Option<(f64, Decision)> = None;
        for i in 0..apps.len() {
            for j in i + 1..apps.len() {
                let (ki, kj) = (apps[i], apps[j]);
                let ipc1 = measured_solo_ipc(coord, &ki.spec);
                let ipc2 = measured_solo_ipc(coord, &kj.spec);
                for (b1, b2) in feasible_splits(&coord.gpu, &ki.spec, &kj.spec) {
                    let (s1, s2) = (b1 * coord.gpu.num_sms, b2 * coord.gpu.num_sms);
                    let m = coord.simcache.pair(&ki.spec, s1, b1, &kj.spec, s2, b2);
                    let cp = kernelet::model::co_scheduling_profit(&[ipc1, ipc2], &m.cipc);
                    if cp < coord.cp_min {
                        continue;
                    }
                    if best.as_ref().map_or(true, |(bcp, _)| cp > *bcp) {
                        let (z1, z2) = kernelet::model::balanced_slice_sizes(
                            &coord.gpu,
                            &ki.spec,
                            b1,
                            m.cipc[0].max(1e-6),
                            coord.min_slice(&ki.spec),
                            &kj.spec,
                            b2,
                            m.cipc[1].max(1e-6),
                            coord.min_slice(&kj.spec),
                        );
                        best = Some((
                            cp,
                            Decision { k1: ki.id, k2: kj.id, b1, b2, size1: z1, size2: z2 },
                        ));
                    }
                }
            }
        }
        best.map(|(_, d)| d)
    }

    fn select_random(
        coord: &Coordinator,
        pending: &[&KernelInstance],
        rng: &mut Xoshiro256,
    ) -> Option<Decision> {
        let mut apps: Vec<&KernelInstance> = Vec::new();
        for inst in pending {
            if !apps.iter().any(|k| k.spec.name == inst.spec.name) {
                apps.push(inst);
            }
        }
        if apps.len() < 2 {
            return None;
        }
        let i = rng.index(apps.len());
        let mut j = rng.index(apps.len() - 1);
        if j >= i {
            j += 1;
        }
        let (ki, kj) = (apps[i], apps[j]);
        let splits = feasible_splits(&coord.gpu, &ki.spec, &kj.spec);
        if splits.is_empty() {
            return None;
        }
        let &(b1, b2) = rng.choose(&splits);
        let m1 = 1 + rng.below(6) as u32;
        let m2 = 1 + rng.below(6) as u32;
        Some(Decision {
            k1: ki.id,
            k2: kj.id,
            b1,
            b2,
            size1: b1 * coord.gpu.num_sms * m1,
            size2: b2 * coord.gpu.num_sms * m2,
        })
    }

    fn measured_solo_ipc(coord: &Coordinator, spec: &KernelSpec) -> f64 {
        coord.profile(spec).ipc
    }

    fn run_with_selector(
        coord: &Coordinator,
        stream: &Stream,
        select: &mut dyn FnMut(&Coordinator, &[&KernelInstance]) -> Option<Decision>,
    ) -> RefReport {
        let gpu = coord.gpu.clone();
        let mut queue: Vec<KernelInstance> = Vec::new();
        let mut upcoming = stream.instances.clone();
        upcoming.reverse();
        let mut clock_cycles = 0.0f64;
        let mut completion = HashMap::new();
        let mut rounds = 0u64;
        let mut solo_slices = 0u64;
        let secs = |c: f64| gpu.cycles_to_secs(c);

        loop {
            while upcoming.last().map_or(false, |k| k.arrival_time <= secs(clock_cycles)) {
                queue.push(upcoming.pop().unwrap());
            }
            if queue.is_empty() {
                match upcoming.last() {
                    Some(k) => {
                        clock_cycles = k.arrival_time * gpu.clock_hz();
                        continue;
                    }
                    None => break,
                }
            }
            let refs: Vec<&KernelInstance> = queue.iter().collect();
            match select(coord, &refs) {
                Some(d) => {
                    let i1 = queue.iter().position(|k| k.id == d.k1).unwrap();
                    let i2 = queue.iter().position(|k| k.id == d.k2).unwrap();
                    loop {
                        let (lo, hi) = if i1 < i2 { (i1, i2) } else { (i2, i1) };
                        let (a, b) = queue.split_at_mut(hi);
                        let (ka, kb) = (&mut a[lo], &mut b[0]);
                        let (k1, k2) = if i1 < i2 { (ka, kb) } else { (kb, ka) };
                        let r1 = k1.take_slice(d.size1.min(k1.remaining_blocks().max(1)));
                        let r2 = k2.take_slice(d.size2.min(k2.remaining_blocks().max(1)));
                        let (n1, n2) = (r1.end - r1.start, r2.end - r2.start);
                        let spec1 = queue[i1].spec.clone();
                        let spec2 = queue[i2].spec.clone();
                        let m = coord.simcache.pair(&spec1, n1, d.b1, &spec2, n2, d.b2);
                        clock_cycles += m.cycles;
                        rounds += 1;
                        let t = secs(clock_cycles);
                        if queue[i1].is_finished() {
                            completion.insert(queue[i1].id, t);
                        }
                        if queue[i2].is_finished() {
                            completion.insert(queue[i2].id, t);
                        }
                        let drained = queue[i1].is_finished() || queue[i2].is_finished();
                        let arrival = upcoming.last().map_or(false, |k| k.arrival_time <= t);
                        if drained || arrival {
                            break;
                        }
                    }
                    queue.retain(|k| !k.is_finished());
                }
                None => {
                    solo_step(
                        coord,
                        &mut queue,
                        &upcoming,
                        &mut clock_cycles,
                        &mut solo_slices,
                        &mut completion,
                    );
                }
            }
        }
        RefReport { total_cycles: clock_cycles, completion, rounds, solo_slices }
    }

    /// The shared solo-fallback step (identical in both seed loops).
    fn solo_step(
        coord: &Coordinator,
        queue: &mut Vec<KernelInstance>,
        upcoming: &[KernelInstance],
        clock_cycles: &mut f64,
        solo_slices: &mut u64,
        completion: &mut HashMap<u64, f64>,
    ) {
        let head = queue
            .iter_mut()
            .min_by(|a, b| a.arrival_time.total_cmp(&b.arrival_time))
            .unwrap();
        let slice = if upcoming.is_empty() {
            head.remaining_blocks()
        } else {
            coord.min_slice(&head.spec).max(head.spec.grid_blocks / 4)
        };
        let r = head.take_slice(slice.min(head.remaining_blocks().max(1)));
        let n = r.end - r.start;
        let spec = head.spec.clone();
        let id = head.id;
        let fin = head.is_finished();
        *clock_cycles += coord.simcache.solo_cycles(&spec, n);
        *solo_slices += 1;
        if fin {
            completion.insert(id, coord.gpu.cycles_to_secs(*clock_cycles));
        }
        queue.retain(|k| !k.is_finished());
    }
}

/// DIFFERENTIAL: the unified engine reproduces the seed loops exactly —
/// same total cycles, same completion times, same round/solo counts —
/// for all four policies, on saturated and Poisson streams.
#[test]
fn engine_matches_seed_loops_differentially() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let streams = [
        Stream::saturated(Mix::MIX, 2, 11),
        Stream::saturated(Mix::ALL, 1, 12),
        Stream::poisson(Mix::MIX, 2, 100.0, 13),
    ];
    for (si, stream) in streams.iter().enumerate() {
        let cases: [(&str, kernelet::coordinator::ExecutionReport, reference::RefReport); 3] = [
            ("kernelet", run_kernelet(&coord, stream), reference::run_kernelet(&coord, stream)),
            ("base", run_base(&coord, stream), reference::run_base(&coord, stream)),
            ("opt", run_opt(&coord, stream), reference::run_opt(&coord, stream)),
        ];
        for (name, engine, seed) in cases {
            assert_eq!(
                engine.total_cycles, seed.total_cycles,
                "{name} stream {si}: total_cycles"
            );
            assert_eq!(engine.completion, seed.completion, "{name} stream {si}: completion");
            assert_eq!(
                engine.coschedule_rounds, seed.rounds,
                "{name} stream {si}: rounds"
            );
            assert_eq!(engine.solo_slices, seed.solo_slices, "{name} stream {si}: solo");
        }
    }
    // MC: identical per-plan seeds must yield identical sample vectors.
    let stream = Stream::saturated(Mix::MIX, 1, 14);
    assert_eq!(
        run_monte_carlo(&coord, &stream, 4, 909),
        reference::run_monte_carlo(&coord, &stream, 4, 909)
    );
}

/// DIFFERENTIAL (QoS tentpole): with QoS disabled — a 100%-batch,
/// no-deadline workload — the refactored engine and the deadline-aware
/// selector are bit-identical to the pre-refactor behavior: the
/// DeadlineSelector defers wholesale to Kernelet, which the frozen
/// `reference` module pins against the seed loops. Whole reports are
/// compared: completion map, slice trace, round/solo counts, queue
/// timeline.
#[test]
fn qos_disabled_is_bit_identical_to_pre_refactor_engine() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let streams = [
        Stream::saturated(Mix::MIX, 2, 31),
        Stream::poisson(Mix::ALL, 2, 120.0, 32),
        Stream::poisson(Mix::MIX, 3, 900.0, 33),
    ];
    for (si, stream) in streams.iter().enumerate() {
        assert!(
            stream.instances.iter().all(|k| k.qos == Qos::BATCH),
            "stream {si}: default workloads must be all-batch/no-deadline"
        );
        let kern = Engine::new(&coord).run(&mut KerneletSelector, stream);
        let dl = Engine::new(&coord).run(&mut DeadlineSelector::new(), stream);
        assert_eq!(dl.total_cycles, kern.total_cycles, "stream {si}: total_cycles");
        assert_eq!(dl.completion, kern.completion, "stream {si}: completion map");
        assert_eq!(dl.coschedule_rounds, kern.coschedule_rounds, "stream {si}: rounds");
        assert_eq!(dl.solo_slices, kern.solo_slices, "stream {si}: solo slices");
        assert_eq!(dl.slice_trace, kern.slice_trace, "stream {si}: slice trace");
        assert_eq!(dl.queue_depth, kern.queue_depth, "stream {si}: queue depth");
        assert_eq!(
            dl.mean_turnaround_secs, kern.mean_turnaround_secs,
            "stream {si}: turnaround"
        );
        // ...and the shared schedule is the pre-refactor one (the
        // frozen seed loop), closing the chain to the seed behavior.
        let frozen = reference::run_kernelet(&coord, stream);
        assert_eq!(dl.total_cycles, frozen.total_cycles, "stream {si}: vs frozen");
        assert_eq!(dl.completion, frozen.completion, "stream {si}: vs frozen completion");
        assert_eq!(dl.coschedule_rounds, frozen.rounds, "stream {si}: vs frozen rounds");
        assert_eq!(dl.solo_slices, frozen.solo_slices, "stream {si}: vs frozen solo");
        // All-batch runs put every kernel in the batch class.
        assert_eq!(dl.qos.batch.completed, stream.len());
        assert_eq!(dl.qos.latency.completed, 0);
        assert_eq!(dl.qos.total_deadline_misses(), 0);
    }
}

/// DIFFERENTIAL: `EngineBuilder` is pure plumbing — an engine built
/// through it is bit-identical to one assembled through the legacy
/// `Engine::new` + `with_*` constructors, with and without an
/// admission gate, on saturated and Poisson streams.
#[test]
fn engine_builder_is_bit_identical_to_legacy_constructors() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let streams = [
        Stream::saturated(Mix::MIX, 2, 31),
        Stream::poisson(Mix::ALL, 2, 120.0, 32),
        Stream::poisson(Mix::MIX, 3, 900.0, 33),
    ];
    for (si, stream) in streams.iter().enumerate() {
        let legacy = Engine::new(&coord).run(&mut KerneletSelector, stream);
        let built = EngineBuilder::new(&coord).build().run(&mut KerneletSelector, stream);
        assert_eq!(built.total_cycles, legacy.total_cycles, "stream {si}: total_cycles");
        assert_eq!(built.completion, legacy.completion, "stream {si}: completion map");
        assert_eq!(built.slice_trace, legacy.slice_trace, "stream {si}: slice trace");
        assert_eq!(built.queue_depth, legacy.queue_depth, "stream {si}: queue depth");
        assert_eq!(built.coschedule_rounds, legacy.coschedule_rounds, "stream {si}: rounds");
        assert_eq!(
            built.mean_turnaround_secs, legacy.mean_turnaround_secs,
            "stream {si}: turnaround"
        );

        // Same pin through the admission axis (the deprecated shim
        // must keep delegating to exactly what the builder wires up).
        let spec = AdmissionSpec::BacklogCap { cap: 4 };
        #[allow(deprecated)]
        let legacy = Engine::new(&coord)
            .with_admission(spec.build())
            .run_source(&mut KerneletSelector, &mut ReplaySource::from_stream(stream));
        let built = EngineBuilder::new(&coord)
            .admission(spec.build())
            .build()
            .run_source(&mut KerneletSelector, &mut ReplaySource::from_stream(stream));
        assert_eq!(built.total_cycles, legacy.total_cycles, "stream {si}: gated cycles");
        assert_eq!(built.completion, legacy.completion, "stream {si}: gated completion");
        assert_eq!(built.admission, legacy.admission, "stream {si}: gate accounting");
    }
}

/// PROPERTY (crafted two-kernel trace): the deadline-aware selector
/// never misses a deadline FIFO meets, and meets deadlines FIFO
/// misses. A big batch kernel arrives at t=0; a small latency kernel
/// arrives while it runs. FIFO makes the latecomer wait out the whole
/// batch (completion `c_fifo`); co-scheduling/EDF finishes it at
/// `c_qos << c_fifo`. Any deadline ≥ c_fifo is met by both; a deadline
/// between the two is missed by FIFO and met by the deadline policy.
#[test]
fn deadline_selector_never_misses_what_fifo_meets() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let batch_spec = {
        let s = BenchmarkApp::TEA.spec();
        s.with_grid(s.grid_blocks * 8)
    };
    let lat_spec = BenchmarkApp::PC.spec();
    let batch_secs = coord.gpu.cycles_to_secs(coord.simcache.solo_full(&batch_spec));
    let t_arr = 0.3 * batch_secs;
    let trace = |deadline: Option<f64>| -> Vec<KernelInstance> {
        vec![
            KernelInstance::new(0, batch_spec.clone(), 0.0),
            KernelInstance::new(1, lat_spec.clone(), t_arr).with_qos(Qos::latency(deadline)),
        ]
    };
    let run = |sel: &mut dyn kernelet::coordinator::Selector, deadline: Option<f64>| {
        Engine::new(&coord)
            .run_source(sel, &mut ReplaySource::from_instances("crafted", trace(deadline)))
    };

    // Calibrate both policies' latency-kernel completions, deadline-free.
    let c_fifo = run(&mut FifoSelector, None).completion[&1];
    let c_qos = run(&mut DeadlineSelector::new(), None).completion[&1];
    // Craft precondition (and the point of QoS scheduling): the
    // latecomer finishes far earlier than behind-the-batch FIFO.
    assert!(
        c_qos < 0.8 * c_fifo,
        "craft broken: deadline policy {c_qos} not well under fifo {c_fifo}"
    );

    // Deadlines FIFO meets (≥ its completion): the deadline policy
    // must meet every one of them too.
    for scale in [1.0, 1.1, 2.0, 10.0] {
        let dl = c_fifo * scale;
        let fifo = run(&mut FifoSelector, Some(dl));
        let qos = run(&mut DeadlineSelector::new(), Some(dl));
        assert_eq!(fifo.qos.latency.deadline_misses, 0, "scale {scale}: fifo must meet");
        assert_eq!(
            qos.qos.latency.deadline_misses, 0,
            "scale {scale}: deadline policy missed a deadline FIFO meets"
        );
        assert!(qos.completion[&1] <= fifo.completion[&1], "scale {scale}");
    }

    // A deadline between the two completions: FIFO misses, EDF meets.
    let dl = 0.5 * (c_qos + c_fifo);
    let fifo = run(&mut FifoSelector, Some(dl));
    let qos = run(&mut DeadlineSelector::new(), Some(dl));
    assert_eq!(fifo.qos.latency.deadline_misses, 1, "fifo must miss {dl}");
    assert_eq!(qos.qos.latency.deadline_misses, 0, "deadline policy must meet {dl}");
}

/// PROPERTY: take_slice covers each kernel's grid exactly once for
/// arbitrary slice-size sequences.
#[test]
fn slicing_partitions_grid() {
    let mut rng = Xoshiro256::new(0x5111CE);
    for case in 0..50 {
        let spec = BenchmarkApp::ALL[case % 8].spec().with_grid(97 + (case as u32 * 13) % 300);
        let mut inst = KernelInstance::new(case as u64, spec.clone(), 0.0);
        let mut seen = vec![false; spec.grid_blocks as usize];
        while !inst.is_finished() {
            let size = 1 + rng.below(60) as u32;
            for blk in inst.take_slice(size) {
                assert!(!seen[blk as usize], "case {case}: block {blk} twice");
                seen[blk as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "case {case}: blocks missed");
    }
}
