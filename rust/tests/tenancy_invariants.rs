//! Multi-tenancy invariants: the differential pins (a single-tenant
//! `TenantMix` and an inert single-weight fair gate are bit-identical
//! to the pre-tenancy engine on every scenario), the fairness property
//! (under a 10× flood the victim tenant's p99 strictly improves over
//! the tenant-blind deadline selector while its service share stays in
//! its weight band), and the closed-loop backpressure regression
//! (router-shed submissions are retried, not silently dropped).

use kernelet::config::{GpuConfig, SelectorSpec, WorkloadSpec};
use kernelet::coordinator::{
    AdmissionSpec, Coordinator, DeadlineSelector, DispatchPolicy, Engine, EngineBuilder,
    FairShareSelector, KerneletSelector, MultiGpuDispatcher, ShedPoint, TenantStats,
};
use kernelet::figures::throughput::base_capacity_kps;
use kernelet::kernel::TenantId;
use kernelet::workload::{
    scenario_source, ClosedLoopSource, Mix, QosMix, TenantMix, SCENARIO_NAMES,
};

const SEED: u64 = 0x7E_0406;

/// DIFFERENTIAL (the tentpole's zero-cost pin): a single-tenant
/// `TenantMix` leaves every scenario's schedule bit-identical to the
/// pre-tenancy engine — `attach` is the identity, every instance stays
/// [`TenantId::SOLE`], and the report carries exactly one sole-tenant
/// row whose counts partition the run.
#[test]
fn single_tenant_mix_is_bit_identical_on_all_scenarios() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    for scenario in SCENARIO_NAMES {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 4, 2.0 * capacity, SEED, qos)
                .expect("valid scenario")
        };
        let plain = Engine::new(&coord).run_source(&mut KerneletSelector, mk().as_mut());
        let mut stamped = TenantMix::SINGLE.attach(mk());
        let tenanted =
            Engine::new(&coord).run_source(&mut KerneletSelector, stamped.as_mut());
        assert_eq!(tenanted.total_cycles, plain.total_cycles, "{scenario}: total_cycles");
        assert_eq!(tenanted.completion, plain.completion, "{scenario}: completion map");
        assert_eq!(tenanted.slice_trace, plain.slice_trace, "{scenario}: slice trace");
        assert_eq!(tenanted.queue_depth, plain.queue_depth, "{scenario}: queue depth");
        assert_eq!(tenanted.qos, plain.qos, "{scenario}: per-class stats");
        // One sole-tenant row, partitioning the run exactly.
        let rows: &[TenantStats] = &tenanted.tenants;
        assert_eq!(rows.len(), 1, "{scenario}: tenant rows");
        assert_eq!(rows[0].tenant, TenantId::SOLE, "{scenario}");
        assert_eq!(rows[0].stats.completed, tenanted.kernels_completed, "{scenario}");
        assert_eq!(rows[0].shed, 0, "{scenario}");
        assert_eq!(tenanted.shed_retries, 0, "{scenario}");
    }
}

/// DIFFERENTIAL: a fair gate with a single weight has no second tenant
/// to balance against, so `FairShareSelector` must reproduce the plain
/// `DeadlineSelector` schedule bit-for-bit on every scenario —
/// fairness costs nothing when off.
#[test]
fn single_weight_fair_gate_is_bit_identical_to_deadline_selector() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let qos = QosMix::latency_share(0.3, 4.0 / capacity);
    for scenario in SCENARIO_NAMES {
        let mk = || {
            scenario_source(scenario, Mix::MIX, 4, 2.0 * capacity, SEED ^ 1, qos)
                .expect("valid scenario")
        };
        let dl =
            Engine::new(&coord).run_source(&mut DeadlineSelector::new(), mk().as_mut());
        let fair = Engine::new(&coord)
            .run_source(&mut FairShareSelector::new(&[1.0]), mk().as_mut());
        assert_eq!(fair.total_cycles, dl.total_cycles, "{scenario}: total_cycles");
        assert_eq!(fair.completion, dl.completion, "{scenario}: completion map");
        assert_eq!(fair.slice_trace, dl.slice_trace, "{scenario}: slice trace");
        assert_eq!(fair.queue_depth, dl.queue_depth, "{scenario}: queue depth");
        assert_eq!(fair.coschedule_rounds, dl.coschedule_rounds, "{scenario}: rounds");
        assert_eq!(fair.solo_slices, dl.solo_slices, "{scenario}: solo slices");
        assert_eq!(fair.mean_turnaround_secs, dl.mean_turnaround_secs, "{scenario}");
    }
}

/// PROPERTY (the tentpole acceptance): under a bursty 10× flood from
/// tenant 0, the weighted-fair gate keeps the victim tenant inside its
/// weight band and delivers it a strictly better p99 than the
/// tenant-blind deadline selector seeing the identical arrivals.
#[test]
fn fairshare_bounds_the_flood_and_beats_blind_deadline_on_victim_p99() {
    let coord = Coordinator::new(&GpuConfig::c2050());
    let capacity = base_capacity_kps(&coord, Mix::MIX);
    let workload = WorkloadSpec::new("bursty", Mix::MIX)
        .instances(40)
        .load(3.0)
        .seed(SEED ^ 2)
        .qos(QosMix::latency_share(0.3, 4.0 / capacity))
        .tenants(TenantMix::split(&[10.0, 1.0]));
    let run = |spec: SelectorSpec| {
        let mut sel = spec.build();
        let mut src = workload.source(capacity).expect("valid scenario");
        EngineBuilder::new(&coord).build().run_source(sel.as_mut(), src.as_mut())
    };
    let blind = run(SelectorSpec::Deadline { preempt: None });
    let fair = run(SelectorSpec::FairShare { weights: vec![1.0, 1.0], max_lead_secs: None });

    let victim = TenantId(1);
    let row = |rep: &kernelet::coordinator::ExecutionReport| {
        rep.tenant(victim).expect("victim submitted work").clone()
    };
    // Craft check: the flood is real — tenant 0 dominates arrivals.
    let flooder = fair.tenant(TenantId(0)).unwrap();
    assert!(
        flooder.submitted > row(&fair).submitted * 5,
        "craft broken: no flood ({} vs {})",
        flooder.submitted,
        row(&fair).submitted
    );

    // Strictly better victim tail under the fair gate.
    let (p_fair, p_blind) =
        (row(&fair).stats.p99_turnaround_secs, row(&blind).stats.p99_turnaround_secs);
    assert!(p_fair < p_blind, "fair victim p99 {p_fair} !< blind victim p99 {p_blind}");

    // Weight band: the victim's share of charged slice-seconds never
    // starves below half its arrival share and never exceeds its
    // (equal) weight entitlement.
    let total: f64 = fair.tenants.iter().map(|t| t.service_secs).sum();
    let share = row(&fair).service_secs / total;
    let arrival_share = 1.0 / 11.0;
    assert!(share >= 0.5 * arrival_share, "victim starved: share {share}");
    assert!(share <= 0.5 + 0.05, "victim past its weight: share {share}");
}

/// REGRESSION (`ShedPoint::Router`): a closed-loop client whose
/// submission is shed at the router retries with jittered think-time
/// instead of being dropped permanently — the fleet report counts the
/// retries and every retry traces back to a shed.
#[test]
fn router_shed_closed_loop_clients_retry_instead_of_vanishing() {
    let gpus = vec![GpuConfig::c2050(), GpuConfig::c2050()];
    let dispatcher = MultiGpuDispatcher::new(&gpus, DispatchPolicy::RoundRobin)
        .with_admission(AdmissionSpec::BacklogCap { cap: 1 }, ShedPoint::Router);
    // Near-zero think time: 8 clients hammer 2 devices whose router
    // sheds past a 1-deep backlog, so sheds are guaranteed.
    let mut source = ClosedLoopSource::new(Mix::MIX, 8, 1.0e4, 60, SEED ^ 3);
    let rep = dispatcher.run_source(&mut source);
    assert!(rep.admission.total_shed() > 0, "craft broken: router never shed");
    assert!(rep.shed_retries > 0, "shed clients never retried");
    // Every retry was provoked by a shed (retries re-enter as fresh
    // submissions, so sheds can exceed retries but never the reverse).
    assert!(
        rep.shed_retries <= rep.admission.total_shed() as u64,
        "retries {} > sheds {}",
        rep.shed_retries,
        rep.admission.total_shed()
    );
    // The per-tenant rows see the router sheds too (sole tenant here).
    let sole = rep.tenant(TenantId::SOLE).expect("sole tenant row");
    assert_eq!(sole.shed as usize, rep.admission.total_shed(), "router sheds not attributed");

    // Same client behavior on the single-device engine path: the
    // device-side gate triggers `on_shed` through `run_source`.
    let coord = Coordinator::new(&GpuConfig::c2050());
    let mut source = ClosedLoopSource::new(Mix::MIX, 8, 1.0e4, 60, SEED ^ 4);
    let rep = EngineBuilder::new(&coord)
        .admission(AdmissionSpec::BacklogCap { cap: 1 }.build())
        .build()
        .run_source(&mut KerneletSelector, &mut source);
    assert!(rep.admission.total_shed() > 0, "craft broken: engine gate never shed");
    assert!(rep.shed_retries > 0, "engine-path shed clients never retried");
}
