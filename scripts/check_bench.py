#!/usr/bin/env python3
"""Gate the BENCH_*.json perf artifacts: schema checks + baseline drift.

Stdlib only (runs in containers with nothing but python3). Two jobs:

1. **Schema + acceptance checks** for every bench kind the repo emits
   (`BENCH_model.json`, `BENCH_scheduling.json`, `BENCH_throughput.json`,
   `BENCH_qos.json`, `BENCH_admission.json`, `BENCH_routing.json`,
   `BENCH_tenancy.json`, `BENCH_resilience.json`):
   structure, coverage
   (scenarios x policies x fleets), and the semantic acceptance bars —
   the deadline policy must not lose to class-blind Kernelet on the
   latency class under bursty overload (qos), the SLO guard must not
   lose to the open door while shedding only batch-class kernels, with
   the per-class completed + shed + deferred_unfinished + incomplete
   counts summing exactly to arrivals in every cell (admission),
   ETA-driven routing (`efc`) must not lose to `sloaware` on fleet
   latency-class deadline misses at the bursty peak load (routing),
   the weighted-fair gate must keep the flooded victim tenant inside
   its weight band and never lose to the tenant-blind deadline
   selector on the victim's p99 at the bursty peak (tenancy), and the
   fault drills must stay available — a mid-run drain strands nothing,
   re-routes at least one kernel and holds during-fault goodput at
   >= 50% of pre-fault, a 3x slowdown is detected by ETA calibration
   (the degraded device's correction exceeds every healthy device's),
   and the autoscaled flash-crowd fleet scales up and strictly beats
   the fixed fleet on goodput (resilience).

2. **Baseline comparison**: fresh files are compared against committed
   baselines (default `scripts/baselines/`) with a +/-15% tolerance on
   the simulated throughput/goodput/p99 metrics; BENCH_scheduling's
   deterministic event counts (`events.total`) get the same drift slot.
   Wall-clock metrics (BENCH_scheduling's *_ns and events_per_sec,
   every file's wall_ms) are machine-dependent and never compared. `--bless` records the fresh files as the new
   baselines; a missing baseline is reported but does not fail (the
   first CI machine blesses it).

Usage:
    check_bench.py [--baseline-dir DIR] [--bless] [--schema-only] [FILE...]

`--schema-only` needs no toolchain and no fresh bench run: it
self-tests the validators against embedded example documents and
validates any committed baselines, so compile-review-only environments
still validate the JSON shapes.
"""

import argparse
import json
import pathlib
import shutil
import sys

TOLERANCE = 0.15  # relative drift allowed on compared metrics
ABS_EPS = 1e-6  # absolute slack for near-zero seconds values

FAILURES = []
QUIET = False  # suppress FAIL prints while running expected-negative self-tests


def fail(msg):
    FAILURES.append(msg)
    if not QUIET:
        print(f"FAIL: {msg}")


def check(cond, msg):
    if not cond:
        fail(msg)
    return cond


# ---------------------------------------------------------------------
# Schema + acceptance validators (one per "bench" tag)
# ---------------------------------------------------------------------

def validate_scheduling(d, name):
    check(d.get("bench") == "scheduling", f"{name}: wrong bench tag {d.get('bench')!r}")
    results = d.get("results", [])
    check(bool(results), f"{name}: no results recorded")
    for r in results:
        check(r.get("iters", 0) >= 1, f"{name}: {r.get('name')}: bad iters")
        check(r.get("mean_ns", 0) > 0, f"{name}: {r.get('name')}: bad mean_ns")
    # Engine event rate: arrivals + completions + dispatch decisions
    # over one timed run. The counts are simulated-deterministic (drift
    # gated against the baseline); events_per_sec is wall-clock and only
    # schema-checked.
    ev = d.get("events")
    if check(isinstance(ev, dict), f"{name}: missing events block (events-per-second metric)"):
        check(bool(ev.get("workload")), f"{name}: events.workload missing")
        for k in ("arrivals", "completions", "decisions", "total"):
            v = ev.get(k)
            check(isinstance(v, int) and v >= 0, f"{name}: events.{k} bad: {v!r}")
        check(
            ev.get("total")
            == ev.get("arrivals", 0) + ev.get("completions", 0) + ev.get("decisions", 0),
            f"{name}: events.total {ev.get('total')} != arrivals+completions+decisions",
        )
        check(ev.get("wall_s", 0) > 0, f"{name}: events.wall_s bad")
        check(ev.get("events_per_sec", 0) > 0, f"{name}: events.events_per_sec bad")


def validate_throughput(d, name):
    check(d.get("bench") == "throughput", f"{name}: wrong bench tag {d.get('bench')!r}")
    curves = d.get("curves", [])
    check(bool(curves), f"{name}: no curves recorded")
    scenarios = {c["scenario"] for c in curves}
    policies = {c["policy"] for c in curves}
    check(len(scenarios) >= 3, f"{name}: need >=3 scenarios, got {sorted(scenarios)}")
    check(len(policies) >= 2, f"{name}: need >=2 policies, got {sorted(policies)}")
    for c in curves:
        label = f"{name}: {c['scenario']}/{c['policy']}"
        check(bool(c["points"]), f"{label}: empty curve")
        for p in c["points"]:
            check(p["throughput_kps"] > 0, f"{label}: dead point at load {p.get('load')}")
    fleet = d.get("fleet_curves", [])
    check(bool(fleet), f"{name}: no fleet curves recorded")
    routing = {c["policy"] for c in fleet}
    check(
        routing >= {"roundrobin", "leastloaded", "sloaware"},
        f"{name}: missing routing policies: {sorted(routing)}",
    )
    gpus = {c["gpus"] for c in fleet}
    check(len(gpus) >= 2, f"{name}: fleet sweep must scale device counts, got {sorted(gpus)}")
    for c in fleet:
        label = f"{name}: {c['scenario']}/{c['policy']}/x{c['gpus']}"
        check(bool(c["points"]), f"{label}: empty fleet curve")
        for p in c["points"]:
            check(p["throughput_kps"] > 0, f"{label}: dead fleet point")


def validate_qos(d, name):
    check(d.get("bench") == "qos", f"{name}: wrong bench tag {d.get('bench')!r}")
    check(0.0 < d.get("latency_fraction", 0) <= 1.0, f"{name}: bad latency_fraction")
    check(d.get("deadline_scale", 0) > 0.0, f"{name}: bad deadline_scale")
    curves = d.get("curves", [])
    check(
        {c["policy"] for c in curves} >= {"kernelet", "deadline"},
        f"{name}: missing QoS policies",
    )
    by = {(c["scenario"], c["policy"]): c["points"] for c in curves}
    for key, pts in by.items():
        check(bool(pts), f"{name}: empty QoS curve {key}")
        for p in pts:
            for cls in ("latency", "batch"):
                c = p[cls]
                check(
                    c["deadline_misses"] <= max(c["with_deadline"], 1),
                    f"{name}: {key} load {p['load']}: {cls} misses exceed deadlined",
                )
                check(
                    c["p50_s"] <= c["p99_s"] + 1e-12,
                    f"{name}: {key} load {p['load']}: {cls} percentiles unordered",
                )

    # Acceptance: under bursty overload the deadline policy is never
    # worse than class-blind Kernelet on the latency class, and strictly
    # better whenever Kernelet actually misses deadlines (a quiet
    # quick-mode run where nobody misses proves nothing either way and
    # must not fail CI).
    if ("bursty", "kernelet") in by and ("bursty", "deadline") in by:
        peak = lambda pol: max(by[("bursty", pol)], key=lambda p: p["load"])["latency"]
        k, dl = peak("kernelet"), peak("deadline")
        check(
            dl["p99_s"] <= k["p99_s"] + ABS_EPS,
            f"{name}: deadline p99 {dl['p99_s']} > kernelet {k['p99_s']} at bursty peak",
        )
        check(
            dl["deadline_misses"] <= k["deadline_misses"],
            f"{name}: deadline misses {dl['deadline_misses']} > kernelet {k['deadline_misses']}",
        )
        if k["deadline_misses"] > 0:
            check(
                dl["deadline_misses"] < k["deadline_misses"] or dl["p99_s"] < k["p99_s"],
                f"{name}: EDF gating bought nothing under bursty overload",
            )
    else:
        fail(f"{name}: bursty kernelet/deadline curves missing")


def validate_admission(d, name):
    check(d.get("bench") == "admission", f"{name}: wrong bench tag {d.get('bench')!r}")
    check(0.0 < d.get("latency_fraction", 0) <= 1.0, f"{name}: bad latency_fraction")
    check(d.get("deadline_scale", 0) > 0.0, f"{name}: bad deadline_scale")
    check(d.get("backlog_cap", 0) >= 1, f"{name}: bad backlog_cap")
    curves = d.get("curves", [])
    policies = {c["policy"] for c in curves}
    check(
        policies >= {"admitall", "backlogcap", "sloguard"},
        f"{name}: missing admission policies: {sorted(policies)}",
    )
    scenarios = {c["scenario"] for c in curves}
    check(len(scenarios) >= 2, f"{name}: need >=2 scenarios, got {sorted(scenarios)}")
    by = {(c["scenario"], c["policy"]): c["points"] for c in curves}
    for (scenario, policy), pts in by.items():
        check(bool(pts), f"{name}: empty admission curve {scenario}/{policy}")
        for p in pts:
            label = f"{name}: {scenario}/{policy} load {p['load']}"
            total = 0
            for cls in ("latency", "batch"):
                c = p[cls]
                # The CI-gated partition: every arrival is accounted
                # exactly once.
                parts = (
                    c["completed"] + c["shed"] + c["deferred_unfinished"] + c["incomplete"]
                )
                check(
                    parts == c["arrivals"],
                    f"{label}: {cls} partition {parts} != arrivals {c['arrivals']}",
                )
                check(
                    c["p50_s"] <= c["p99_s"] + 1e-12,
                    f"{label}: {cls} percentiles unordered",
                )
                total += c["arrivals"]
            check(total == p["arrivals"], f"{label}: class arrivals don't sum to total")
            check(
                p["goodput_kps"] <= p["throughput_kps"] + ABS_EPS,
                f"{label}: goodput exceeds throughput",
            )
            if policy == "admitall":
                check(
                    p["completed"] == p["arrivals"],
                    f"{label}: the open door must run everything",
                )
            if policy == "sloguard":
                lat = p["latency"]
                check(
                    lat["shed"] == 0 and lat["deferred_unfinished"] == 0,
                    f"{label}: sloguard touched the latency class",
                )

    # Acceptance: under bursty overload the SLO guard is never worse
    # than the open door on latency-class p99 and misses, and strictly
    # better whenever the open door actually misses.
    if ("bursty", "admitall") in by and ("bursty", "sloguard") in by:
        peak = lambda pol: max(by[("bursty", pol)], key=lambda p: p["load"])["latency"]
        open_door, guard = peak("admitall"), peak("sloguard")
        check(
            guard["p99_s"] <= open_door["p99_s"] + ABS_EPS,
            f"{name}: sloguard p99 {guard['p99_s']} > admitall {open_door['p99_s']} at bursty peak",
        )
        check(
            guard["deadline_misses"] <= open_door["deadline_misses"],
            f"{name}: sloguard misses {guard['deadline_misses']} > admitall {open_door['deadline_misses']}",
        )
        if open_door["deadline_misses"] > 0:
            check(
                guard["deadline_misses"] < open_door["deadline_misses"]
                or guard["p99_s"] < open_door["p99_s"],
                f"{name}: load shedding bought nothing under bursty overload",
            )
    else:
        fail(f"{name}: bursty admitall/sloguard curves missing")


def validate_routing(d, name):
    check(d.get("bench") == "routing", f"{name}: wrong bench tag {d.get('bench')!r}")
    check(0.0 < d.get("latency_fraction", 0) <= 1.0, f"{name}: bad latency_fraction")
    check(d.get("deadline_scale", 0) > 0.0, f"{name}: bad deadline_scale")
    gpus = d.get("gpus", 0)
    check(gpus >= 2, f"{name}: routing needs a fleet, got gpus={gpus}")
    curves = d.get("curves", [])
    policies = {c["policy"] for c in curves}
    check(
        policies >= {"roundrobin", "leastloaded", "sloaware", "efc"},
        f"{name}: missing routing policies: {sorted(policies)}",
    )
    scenarios = {c["scenario"] for c in curves}
    check(len(scenarios) >= 2, f"{name}: need >=2 scenarios, got {sorted(scenarios)}")
    by = {(c["scenario"], c["policy"]): c["points"] for c in curves}
    for (scenario, policy), pts in by.items():
        check(bool(pts), f"{name}: empty routing curve {scenario}/{policy}")
        for p in pts:
            label = f"{name}: {scenario}/{policy} load {p['load']}"
            for cls in ("latency", "batch"):
                c = p[cls]
                check(
                    c["deadline_misses"] <= max(c["with_deadline"], 1),
                    f"{label}: {cls} misses exceed deadlined",
                )
                check(
                    c["p50_s"] <= c["p99_s"] + 1e-12,
                    f"{label}: {cls} percentiles unordered",
                )
            check(
                p["goodput_kps"] <= p["throughput_kps"] + ABS_EPS,
                f"{label}: goodput exceeds throughput",
            )
            eta = p.get("eta", [])
            if policy == "efc":
                # ETA calibration must be observable: one stats entry
                # per device, non-negative error, bounded correction.
                check(len(eta) == gpus, f"{label}: eta entries {len(eta)} != gpus {gpus}")
                for e in eta:
                    check(e["samples"] >= 0, f"{label}: negative eta samples")
                    check(e["mean_abs_err_s"] >= 0.0, f"{label}: negative eta error")
                    check(e["correction"] > 0.0, f"{label}: non-positive eta correction")
            else:
                check(not eta, f"{label}: non-efc point carries eta stats")

    # Acceptance (the tentpole bar): at the bursty peak load, EFC
    # routing must not lose to SloAware on fleet latency-class deadline
    # misses.
    if ("bursty", "sloaware") in by and ("bursty", "efc") in by:
        peak = lambda pol: max(by[("bursty", pol)], key=lambda p: p["load"])["latency"]
        slo, efc = peak("sloaware"), peak("efc")
        check(
            efc["deadline_misses"] <= slo["deadline_misses"],
            f"{name}: efc misses {efc['deadline_misses']} > sloaware "
            f"{slo['deadline_misses']} at bursty peak",
        )
    else:
        fail(f"{name}: bursty sloaware/efc curves missing")


def validate_tenancy(d, name):
    check(d.get("bench") == "tenancy", f"{name}: wrong bench tag {d.get('bench')!r}")
    check(0.0 < d.get("latency_fraction", 0) <= 1.0, f"{name}: bad latency_fraction")
    check(d.get("deadline_scale", 0) > 0.0, f"{name}: bad deadline_scale")
    shares = d.get("tenant_shares", [])
    weights = d.get("fair_weights", [])
    if not check(
        len(shares) >= 2 and all(s > 0 for s in shares),
        f"{name}: bad tenant_shares {shares}",
    ):
        return
    check(
        len(weights) == len(shares) and all(w > 0 for w in weights),
        f"{name}: fair_weights {weights} don't match tenant_shares",
    )
    curves = d.get("curves", [])
    policies = {c["policy"] for c in curves}
    check(
        policies >= {"deadline", "fairshare"},
        f"{name}: missing tenancy policies: {sorted(policies)}",
    )
    scenarios = {c["scenario"] for c in curves}
    check(len(scenarios) >= 2, f"{name}: need >=2 scenarios, got {sorted(scenarios)}")
    by = {(c["scenario"], c["policy"]): c["points"] for c in curves}
    for (scenario, policy), pts in by.items():
        check(bool(pts), f"{name}: empty tenancy curve {scenario}/{policy}")
        for p in pts:
            label = f"{name}: {scenario}/{policy} load {p['load']}"
            check(p.get("kernels", 0) > 0, f"{label}: dead point")
            check(p.get("throughput_kps", 0) > 0, f"{label}: no throughput")
            rows = p.get("tenants", [])
            check(
                len(rows) == len(shares),
                f"{label}: {len(rows)} tenant rows != {len(shares)} tenants",
            )
            total_share = 0.0
            for t in rows:
                tl = f"{label} tenant {t.get('tenant')}"
                check(t["completed"] <= t["submitted"], f"{tl}: completed exceeds submitted")
                check(0.0 <= t["share"] <= 1.0, f"{tl}: share out of [0, 1]")
                check(t["service_secs"] >= 0.0, f"{tl}: negative service")
                check(t["shed"] >= 0, f"{tl}: negative shed")
                check(t["p50_s"] <= t["p99_s"] + 1e-12, f"{tl}: percentiles unordered")
                total_share += t["share"]
            # Shares are service_secs / total, so they partition the run.
            if any(t.get("service_secs", 0) > 0 for t in rows):
                check(
                    abs(total_share - 1.0) <= 1e-6,
                    f"{label}: tenant shares sum to {total_share}, not 1",
                )

    # Acceptance (the tentpole bar): at the bursty peak load, the
    # weighted-fair gate must keep the flooded victim tenant (smallest
    # arrival share) inside its weight band — not starved below half its
    # arrival share, not above its weight entitlement — and never lose
    # to the tenant-blind deadline selector on the victim's p99;
    # strictly better whenever the blind run actually misses deadlines
    # (a quiet quick-mode run where nobody misses proves nothing).
    if ("bursty", "deadline") in by and ("bursty", "fairshare") in by:
        victim = shares.index(min(shares))

        def peak(pol):
            p = max(by[("bursty", pol)], key=lambda p: p["load"])
            return next(t for t in p["tenants"] if t["tenant"] == victim)

        blind, fair = peak("deadline"), peak("fairshare")
        check(
            fair["p99_s"] <= blind["p99_s"] + ABS_EPS,
            f"{name}: fairshare victim p99 {fair['p99_s']} > deadline "
            f"{blind['p99_s']} at bursty peak",
        )
        if blind["deadline_misses"] > 0:
            check(
                fair["deadline_misses"] < blind["deadline_misses"]
                or fair["p99_s"] < blind["p99_s"],
                f"{name}: fair gate bought the victim nothing under the bursty flood",
            )
        arrival_share = shares[victim] / sum(shares)
        entitlement = weights[victim] / sum(weights)
        check(
            fair["share"] >= 0.5 * arrival_share,
            f"{name}: victim starved under fairshare: share {fair['share']} < "
            f"half its arrival share {arrival_share}",
        )
        check(
            fair["share"] <= entitlement + 0.05,
            f"{name}: victim past its weight entitlement {entitlement}: "
            f"share {fair['share']}",
        )
    else:
        fail(f"{name}: bursty deadline/fairshare curves missing")


def validate_resilience(d, name):
    check(d.get("bench") == "resilience", f"{name}: wrong bench tag {d.get('bench')!r}")
    check(0.0 < d.get("latency_fraction", 0) <= 1.0, f"{name}: bad latency_fraction")
    check(d.get("deadline_scale", 0) > 0.0, f"{name}: bad deadline_scale")
    check(d.get("load", 0) > 0.0, f"{name}: bad load")
    gpus = d.get("gpus", 0)
    check(gpus >= 2, f"{name}: resilience needs a fleet, got gpus={gpus}")
    drills = d.get("drills", [])
    by = {(x.get("mode"), x.get("policy")): x for x in drills}
    modes = {m for (m, _p) in by}
    check(
        modes >= {"none", "drain", "slowdown"},
        f"{name}: missing drills: {sorted(modes)}",
    )
    for (mode, policy), x in by.items():
        label = f"{name}: {mode}/{policy}"
        check(x.get("kernels", 0) > 0, f"{label}: dead drill")
        for k in ("goodput_kps", "pre_kps", "during_kps", "post_kps"):
            v = x.get(k)
            check(isinstance(v, (int, float)) and v >= 0, f"{label}: bad {k}: {v!r}")
        check(x.get("stranded", -1) >= 0, f"{label}: bad stranded count")
        check(x.get("rerouted", -1) >= 0, f"{label}: bad rerouted count")
        check(x.get("reroute_latency_s", -1) >= 0, f"{label}: negative re-route latency")
        corr = x.get("corrections", [])
        if policy == "efc":
            # Calibration must be observable: one correction per device.
            check(len(corr) == gpus, f"{label}: corrections {len(corr)} != gpus {gpus}")
            for c in corr:
                check(c > 0.0, f"{label}: non-positive eta correction")
        else:
            check(not corr, f"{label}: non-efc drill carries corrections")
        if mode == "none":
            # The empty plan is inert: no events, nothing re-routed.
            check(
                x.get("rerouted") == 0 and x.get("stranded") == 0,
                f"{label}: empty fault plan re-routed or stranded kernels",
            )

    # Acceptance (availability bar): losing a device mid-run must not
    # collapse the fleet — nothing stranded, at least one kernel
    # re-routed, during-fault goodput >= 50% of pre-fault.
    drain = by.get(("drain", "efc"))
    if check(drain is not None, f"{name}: drain/efc drill missing"):
        check(drain["stranded"] == 0, f"{name}: drain stranded {drain['stranded']} kernels")
        check(drain["rerouted"] >= 1, f"{name}: drain re-routed nothing")
        check(
            drain["during_kps"] >= 0.5 * drain["pre_kps"],
            f"{name}: drain goodput collapsed: during {drain['during_kps']} < half of "
            f"pre-fault {drain['pre_kps']}",
        )

    # Acceptance (detection bar): a 3x slowdown on the last device must
    # show up in ETA calibration — its correction exceeds every healthy
    # device's.
    slow = by.get(("slowdown", "efc"))
    if check(slow is not None, f"{name}: slowdown/efc drill missing"):
        corr = slow.get("corrections", [])
        if check(len(corr) == gpus, f"{name}: slowdown corrections incomplete: {corr}"):
            degraded, healthy = corr[-1], corr[:-1]
            check(
                all(degraded > c for c in healthy),
                f"{name}: slowdown undetected: degraded correction {degraded} does not "
                f"exceed healthy {healthy}",
            )

    # Acceptance (elasticity bar): under the flash crowd the autoscaler
    # must engage and the elastic fleet must strictly beat the fixed one
    # on goodput.
    fc = d.get("flashcrowd")
    if check(isinstance(fc, dict), f"{name}: missing flashcrowd block"):
        check(fc.get("fixed_gpus", 0) >= 1, f"{name}: bad flashcrowd.fixed_gpus")
        check(
            fc.get("auto_gpus", 0) > fc.get("fixed_gpus", 0),
            f"{name}: elastic fleet has no spare devices",
        )
        check(fc.get("scale_ups", 0) >= 1, f"{name}: autoscaler never scaled up")
        check(
            fc.get("peak_active", 0) > fc.get("fixed_gpus", 0),
            f"{name}: autoscaler never exceeded the fixed fleet size",
        )
        check(
            fc.get("autoscaled_goodput_kps", 0) > fc.get("fixed_goodput_kps", float("inf")),
            f"{name}: autoscaled goodput {fc.get('autoscaled_goodput_kps')} does not beat "
            f"fixed {fc.get('fixed_goodput_kps')}",
        )


MODEL_COUNTERS = (
    "memo_hits",
    "memo_misses",
    "linear_candidates",
    "binary_candidates",
    "prewarm_requested",
    "prewarm_distinct",
    "prewarm_already_cached",
    "prewarm_filled",
    "warm_absorbed",
    "nonconverged",
)


def validate_model(d, name):
    check(d.get("bench") == "model", f"{name}: wrong bench tag {d.get('bench')!r}")
    # Headline solve rate: wall-clock, so schema-checked only (positive),
    # never compared across runs.
    check(d.get("solves_per_sec", 0) > 0, f"{name}: bad solves_per_sec")
    results = d.get("results", [])
    check(bool(results), f"{name}: no results recorded")
    for r in results:
        check(r.get("iters", 0) >= 1, f"{name}: {r.get('name')}: bad iters")
        check(r.get("mean_ns", 0) > 0, f"{name}: {r.get('name')}: bad mean_ns")
    c = d.get("counters")
    if not check(isinstance(c, dict), f"{name}: missing counters block"):
        return
    for k in MODEL_COUNTERS:
        v = c.get(k)
        check(isinstance(v, int) and v >= 0, f"{name}: counters.{k} bad: {v!r}")
    # The deterministic consistency bars: the binary search must never
    # simulate more candidates than the linear scan it replaced, and the
    # prewarm arithmetic must partition exactly.
    check(
        0 < c.get("binary_candidates", 0) <= c.get("linear_candidates", 0),
        f"{name}: binary search simulated {c.get('binary_candidates')} candidates vs "
        f"linear {c.get('linear_candidates')}",
    )
    check(
        c.get("prewarm_distinct", 0) <= c.get("prewarm_requested", 0),
        f"{name}: prewarm distinct exceeds requested",
    )
    check(
        c.get("prewarm_filled", -1)
        == c.get("prewarm_distinct", 0) - c.get("prewarm_already_cached", 0),
        f"{name}: prewarm filled {c.get('prewarm_filled')} != distinct - already_cached",
    )
    check(
        c.get("warm_absorbed", 0) >= c.get("prewarm_distinct", 0),
        f"{name}: warm transfer absorbed {c.get('warm_absorbed')} entries, fewer than the "
        f"{c.get('prewarm_distinct')} the donor prewarmed",
    )


VALIDATORS = {
    "model": validate_model,
    "scheduling": validate_scheduling,
    "throughput": validate_throughput,
    "qos": validate_qos,
    "admission": validate_admission,
    "routing": validate_routing,
    "tenancy": validate_tenancy,
    "resilience": validate_resilience,
}


# ---------------------------------------------------------------------
# Baseline comparison
# ---------------------------------------------------------------------

# Dotted key paths compared per point, by bench kind. Simulated-time
# metrics only: deterministic given the seed and scale, so drift means
# a real behavior change, not machine noise.
COMPARE_KEYS = {
    "throughput": ["throughput_kps"],
    "qos": ["throughput_kps", "latency.p99_s", "batch.p99_s"],
    "admission": ["throughput_kps", "goodput_kps", "latency.p99_s"],
    "routing": ["throughput_kps", "goodput_kps", "latency.p99_s"],
    # Per-tenant rows are a list (not addressable by dotted path); the
    # point-level kernel count and throughput are the deterministic
    # drift signals.
    "tenancy": ["kernels", "throughput_kps"],
}


def dig(obj, dotted):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def curve_index(d):
    """(scenario, policy[, gpus]) -> {load -> point} for every curve
    section present in the document."""
    out = {}
    for section in ("curves", "fleet_curves"):
        for c in d.get(section, []):
            key = (section, c.get("scenario"), c.get("policy"), c.get("gpus"))
            out[key] = {p["load"]: p for p in c.get("points", [])}
    return out


def within(a, b):
    if a is None or b is None:
        return True  # key absent on one side: schema change, not drift
    return abs(a - b) <= max(TOLERANCE * max(abs(a), abs(b)), ABS_EPS)


def compare_to_baseline(fresh, base, kind, name):
    if fresh.get("instances_per_app") != base.get("instances_per_app"):
        print(
            f"note: {name}: instances_per_app {fresh.get('instances_per_app')} != baseline "
            f"{base.get('instances_per_app')} — different scale, skipping drift comparison"
        )
        return
    if kind == "model":
        # solves_per_sec and every *_ns figure are wall-clock (machine
        # noise, never compared), but the work counters are exactly
        # deterministic: the bench snapshots the memo stats before any
        # parallel section, the slicer candidate counts are a pure
        # function of the fixed (gpu, app, budget) grid, and the
        # prewarm/absorb counts are cache-entry arithmetic. Any change
        # is a behavior change: gate exactly, not with the drift slot.
        for key in MODEL_COUNTERS:
            a, b = dig(fresh, f"counters.{key}"), dig(base, f"counters.{key}")
            if a != b:
                fail(
                    f"{name}: counters.{key} {a} != baseline {b} "
                    f"(deterministic work count changed)"
                )
        print(
            f"{name}: {len(MODEL_COUNTERS)} deterministic counters compared exactly; "
            f"wall-clock metrics (solves_per_sec, *_ns) not compared"
        )
        return
    if kind == "scheduling":
        # The *_ns timings and events_per_sec are wall-clock (machine
        # noise), but the event *counts* are simulated-deterministic:
        # a drift in events.total means the engine made a different
        # number of decisions — a behavior change, gate it.
        a, b = dig(fresh, "events.total"), dig(base, "events.total")
        if not within(a, b):
            fail(
                f"{name}: events.total {a} drifted >{TOLERANCE:.0%} from baseline {b} "
                f"(decision-count change on the fixed workload)"
            )
        print(
            f"{name}: events.total compared ({a} vs baseline {b}); wall-clock metrics "
            f"(*_ns, events_per_sec) not compared"
        )
        return
    keys = COMPARE_KEYS.get(kind, [])
    if not keys:
        print(f"note: {name}: wall-clock bench, schema-checked only (no drift comparison)")
        return
    fresh_idx, base_idx = curve_index(fresh), curve_index(base)
    for ckey, base_pts in base_idx.items():
        if ckey not in fresh_idx:
            fail(f"{name}: curve {ckey} present in baseline but missing from fresh run")
            continue
        for load, bp in base_pts.items():
            fp = fresh_idx[ckey].get(load)
            if fp is None:
                fail(f"{name}: point load={load} of {ckey} missing from fresh run")
                continue
            for key in keys:
                a, b = dig(fp, key), dig(bp, key)
                if not within(a, b):
                    fail(
                        f"{name}: {ckey} load={load} {key}: {a} drifted >"
                        f"{TOLERANCE:.0%} from baseline {b}"
                    )
    print(f"{name}: baseline comparison done ({len(base_idx)} curves, keys {keys})")


# ---------------------------------------------------------------------
# Embedded self-test documents (--schema-only has real content even in
# containers that never ran a bench)
# ---------------------------------------------------------------------

def _cls(arrivals, completed, shed=0, deferred=0, misses=0, deadlined=0, p99=0.03):
    return {
        "arrivals": arrivals,
        "completed": completed,
        "shed": shed,
        "deferred_unfinished": deferred,
        "incomplete": arrivals - completed - shed - deferred,
        "p50_s": p99 / 3,
        "p95_s": p99 / 2,
        "p99_s": p99,
        "mean_s": p99 / 3,
        "deadline_misses": misses,
        "with_deadline": deadlined,
    }


def _admission_point(load, policy):
    if policy == "admitall":
        lat = _cls(10, 10, misses=4, deadlined=10, p99=0.5)
        bat = _cls(30, 30)
    elif policy == "sloguard":
        lat = _cls(10, 10, misses=1, deadlined=10, p99=0.1)
        bat = _cls(30, 20, shed=6, deferred=4)
    else:
        lat = _cls(10, 8, shed=2, misses=2, deadlined=10, p99=0.2)
        bat = _cls(30, 24, shed=6)
    completed = lat["completed"] + bat["completed"]
    return {
        "load": load,
        "arrivals": 40,
        "completed": completed,
        "throughput_kps": 100.0,
        "goodput_kps": 90.0,
        "latency": lat,
        "batch": bat,
    }


def _routing_point(load, policy):
    misses = {"roundrobin": 9, "leastloaded": 6, "sloaware": 4, "efc": 2}[policy]
    point = {
        "load": load,
        "kernels": 200,
        "throughput_kps": 100.0,
        "goodput_kps": 95.0,
        "preemptions": 3 if policy == "efc" else 0,
        "latency": _qos_cls(0.1 if policy == "efc" else 0.3, misses, 60),
        "batch": _qos_cls(0.2, 0, 0),
        "eta": [],
    }
    if policy == "efc":
        point["eta"] = [
            {"samples": 100, "mean_abs_err_s": 0.004, "mean_err_s": -0.001, "correction": 0.92}
            for _ in range(2)
        ]
    return point


def _tenant_row(tenant, submitted, share, p99, misses=0, shed=0):
    return {
        "tenant": tenant,
        "submitted": submitted,
        "completed": submitted - shed,
        "share": share,
        "service_secs": share * 2.0,
        "shed": shed,
        "p50_s": p99 / 3,
        "p99_s": p99,
        "deadline_misses": misses,
        "goodput_kps": 50.0,
    }


def _tenancy_point(load, policy):
    victim_p99 = 0.1 if policy == "fairshare" else 0.5
    victim_misses = 1 if policy == "fairshare" else 5
    return {
        "load": load,
        "kernels": 220,
        "throughput_kps": 100.0,
        "tenants": [
            _tenant_row(0, 200, 0.9, 0.3),
            _tenant_row(1, 20, 0.1, victim_p99, misses=victim_misses),
        ],
    }


def _resilience_drill(mode, policy):
    x = {
        "mode": mode,
        "policy": policy,
        "kernels": 100,
        "goodput_kps": 90.0,
        "pre_kps": 100.0,
        "during_kps": 100.0 if mode == "none" else 72.0,
        "post_kps": 100.0 if mode == "none" else 85.0,
        "rerouted": 12 if mode == "drain" else 0,
        "stranded": 0,
        "reroute_latency_s": 0.004 if mode == "drain" else 0.0,
        "deadline_misses": 3,
        "corrections": [],
    }
    if policy == "efc":
        x["corrections"] = (
            [1.0, 1.0, 1.0, 2.8] if mode == "slowdown" else [1.0, 1.0, 1.0, 1.0]
        )
    return x


def _qos_cls(p99, misses, deadlined):
    return {
        "completed": 40,
        "p50_s": p99 / 3,
        "p95_s": p99 / 2,
        "p99_s": p99,
        "mean_s": p99 / 3,
        "deadline_misses": misses,
        "with_deadline": deadlined,
    }


EXAMPLES = {
    "model": {
        "bench": "model",
        "solves_per_sec": 850000.0,
        "counters": {
            "memo_hits": 850,
            "memo_misses": 30,
            "linear_candidates": 120,
            "binary_candidates": 52,
            "prewarm_requested": 140,
            "prewarm_distinct": 96,
            "prewarm_already_cached": 0,
            "prewarm_filled": 96,
            "warm_absorbed": 130,
            "nonconverged": 0,
        },
        "results": [
            {
                "name": "solve::auto_8_chains_reused_scratch",
                "iters": 200,
                "mean_ns": 9500,
                "min_ns": 9000,
                "max_ns": 12000,
            }
        ],
    },
    "scheduling": {
        "bench": "scheduling",
        "instances_per_app": 50,
        "events": {
            "workload": "poisson_ALLx25",
            "arrivals": 200,
            "completions": 200,
            "decisions": 450,
            "total": 850,
            "wall_s": 0.012,
            "events_per_sec": 70833.3,
        },
        "results": [
            {"name": "generate::fig13", "iters": 1, "mean_ns": 5, "min_ns": 5, "max_ns": 5}
        ],
    },
    "throughput": {
        "bench": "throughput",
        "instances_per_app": 50,
        "curves": [
            {
                "scenario": s,
                "policy": p,
                "points": [{"load": 1.0, "throughput_kps": 100.0}],
            }
            for s in ("poisson", "bursty", "diurnal")
            for p in ("kernelet", "base")
        ],
        "fleet_curves": [
            {
                "scenario": "poisson",
                "policy": p,
                "gpus": g,
                "points": [{"load": 1.0, "throughput_kps": 100.0, "latency_p99_s": 0.01}],
            }
            for p in ("roundrobin", "leastloaded", "sloaware")
            for g in (1, 2)
        ],
    },
    "qos": {
        "bench": "qos",
        "instances_per_app": 40,
        "latency_fraction": 0.3,
        "deadline_scale": 4.0,
        "curves": [
            {
                "scenario": s,
                "policy": p,
                "points": [
                    {
                        "load": 2.0,
                        "latency": _qos_cls(0.1 if p == "deadline" else 0.5,
                                            1 if p == "deadline" else 5, 40),
                        "batch": _qos_cls(0.2, 0, 0),
                    }
                ],
            }
            for s in ("poisson", "bursty")
            for p in ("kernelet", "deadline")
        ],
    },
    "admission": {
        "bench": "admission",
        "instances_per_app": 40,
        "latency_fraction": 0.25,
        "deadline_scale": 4.0,
        "backlog_cap": 16,
        "curves": [
            {
                "scenario": s,
                "policy": p,
                "points": [_admission_point(3.0, p)],
            }
            for s in ("poisson", "bursty")
            for p in ("admitall", "backlogcap", "sloguard")
        ],
    },
    "routing": {
        "bench": "routing",
        "gpus": 2,
        "instances_per_app": 25,
        "latency_fraction": 0.3,
        "deadline_scale": 4.0,
        "curves": [
            {
                "scenario": s,
                "policy": p,
                "gpus": 2,
                "points": [_routing_point(3.0, p)],
            }
            for s in ("poisson", "bursty")
            for p in ("roundrobin", "leastloaded", "sloaware", "efc")
        ],
    },
    "tenancy": {
        "bench": "tenancy",
        "gpu": "C2050",
        "mix": "MIX",
        "instances_per_app": 40,
        "tenant_shares": [10.0, 1.0],
        "fair_weights": [1.0, 1.0],
        "latency_fraction": 0.3,
        "deadline_scale": 4.0,
        "base_capacity_kps": 120.0,
        "wall_ms": 12,
        "curves": [
            {
                "scenario": s,
                "policy": p,
                "points": [_tenancy_point(l, p) for l in (1.5, 3.0)],
            }
            for s in ("poisson", "bursty")
            for p in ("deadline", "fairshare")
        ],
    },
    "resilience": {
        "bench": "resilience",
        "gpu": "C2050",
        "mix": "MIX",
        "gpus": 4,
        "instances_per_app": 25,
        "latency_fraction": 0.3,
        "deadline_scale": 4.0,
        "load": 1.5,
        "base_capacity_kps": 120.0,
        "wall_ms": 20,
        "drills": [
            _resilience_drill(m, p)
            for m in ("none", "drain", "slowdown")
            for p in ("sloaware", "efc")
        ],
        "flashcrowd": {
            "fixed_gpus": 2,
            "auto_gpus": 4,
            "fixed_goodput_kps": 80.0,
            "autoscaled_goodput_kps": 95.0,
            "fixed_shed": 30,
            "autoscaled_shed": 5,
            "scale_ups": 2,
            "scale_downs": 1,
            "peak_active": 4,
        },
    },
}


def self_test():
    """Validators must accept the embedded examples and reject a
    partition violation — run on every invocation (cheap), and the
    whole payload of --schema-only in toolchain-free containers."""
    for kind, doc in EXAMPLES.items():
        before = len(FAILURES)
        VALIDATORS[kind](doc, f"<example:{kind}>")
        if len(FAILURES) != before:
            fail(f"self-test: embedded {kind} example no longer validates")
    # Negative: a partition violation must be caught.
    global QUIET
    broken = json.loads(json.dumps(EXAMPLES["admission"]))
    broken["curves"][0]["points"][0]["latency"]["completed"] -= 1
    before = len(FAILURES)
    QUIET = True
    validate_admission(broken, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: partition violation slipped through validate_admission")
    else:
        # Expected failures: drop them.
        del FAILURES[before:]
    # Negative: EFC losing to SloAware on bursty-peak misses must be
    # caught (the tentpole acceptance bar).
    broken = json.loads(json.dumps(EXAMPLES["routing"]))
    for c in broken["curves"]:
        if c["scenario"] == "bursty" and c["policy"] == "efc":
            c["points"][0]["latency"]["deadline_misses"] = 99
            c["points"][0]["latency"]["with_deadline"] = 99
    before = len(FAILURES)
    QUIET = True
    validate_routing(broken, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: efc-beats-sloaware violation slipped through validate_routing")
    else:
        del FAILURES[before:]
    # Negative: a fair gate that loses on the flooded victim's p99 at
    # the bursty peak must be caught (the tenancy acceptance bar).
    broken = json.loads(json.dumps(EXAMPLES["tenancy"]))
    for c in broken["curves"]:
        if c["scenario"] == "bursty" and c["policy"] == "fairshare":
            for p in c["points"]:
                p["tenants"][1]["p99_s"] = 0.9
    before = len(FAILURES)
    QUIET = True
    validate_tenancy(broken, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: fairshare-loses-on-victim-p99 slipped through validate_tenancy")
    else:
        del FAILURES[before:]
    # Negative: a starved victim (share below half its arrival share)
    # must be caught even when the tail still looks fine.
    starved = json.loads(json.dumps(EXAMPLES["tenancy"]))
    for c in starved["curves"]:
        if c["scenario"] == "bursty" and c["policy"] == "fairshare":
            for p in c["points"]:
                p["tenants"][0]["share"] = 0.99
                p["tenants"][1]["share"] = 0.01
    before = len(FAILURES)
    QUIET = True
    validate_tenancy(starved, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: starved victim slipped through validate_tenancy")
    else:
        del FAILURES[before:]
    # Negative: a binary search that simulates more candidates than the
    # linear scan, or broken prewarm arithmetic, must be caught.
    worse = json.loads(json.dumps(EXAMPLES["model"]))
    worse["counters"]["binary_candidates"] = worse["counters"]["linear_candidates"] + 1
    unbalanced = json.loads(json.dumps(EXAMPLES["model"]))
    unbalanced["counters"]["prewarm_filled"] += 1
    for doc, what in ((worse, "candidate regression"), (unbalanced, "prewarm arithmetic")):
        before = len(FAILURES)
        QUIET = True
        validate_model(doc, "<negative>")
        QUIET = False
        if len(FAILURES) == before:
            fail(f"self-test: {what} slipped through validate_model")
        else:
            del FAILURES[before:]
    # Negative: a drain whose during-fault goodput collapses below half
    # of pre-fault must be caught (the availability bar).
    broken = json.loads(json.dumps(EXAMPLES["resilience"]))
    for x in broken["drills"]:
        if x["mode"] == "drain" and x["policy"] == "efc":
            x["during_kps"] = 0.2 * x["pre_kps"]
    before = len(FAILURES)
    QUIET = True
    validate_resilience(broken, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: drain goodput collapse slipped through validate_resilience")
    else:
        del FAILURES[before:]
    # Negative: an elastic fleet that fails to beat the fixed fleet on
    # flash-crowd goodput must be caught (the elasticity bar).
    flat = json.loads(json.dumps(EXAMPLES["resilience"]))
    flat["flashcrowd"]["autoscaled_goodput_kps"] = flat["flashcrowd"]["fixed_goodput_kps"]
    before = len(FAILURES)
    QUIET = True
    validate_resilience(flat, "<negative>")
    QUIET = False
    if len(FAILURES) == before:
        fail("self-test: flat autoscaling gain slipped through validate_resilience")
    else:
        del FAILURES[before:]
    # Negative: an inconsistent (or absent) events block must be caught.
    broken = json.loads(json.dumps(EXAMPLES["scheduling"]))
    broken["events"]["total"] += 1
    missing = json.loads(json.dumps(EXAMPLES["scheduling"]))
    del missing["events"]
    for doc, what in ((broken, "inconsistent"), (missing, "missing")):
        before = len(FAILURES)
        QUIET = True
        validate_scheduling(doc, "<negative>")
        QUIET = False
        if len(FAILURES) == before:
            fail(f"self-test: {what} events block slipped through validate_scheduling")
        else:
            del FAILURES[before:]
    print("validator self-test OK")


# ---------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="*", help="fresh BENCH_*.json files to gate")
    ap.add_argument(
        "--baseline-dir",
        default=str(pathlib.Path(__file__).parent / "baselines"),
        help="committed baseline directory (default: scripts/baselines)",
    )
    ap.add_argument("--bless", action="store_true", help="record fresh files as baselines")
    ap.add_argument(
        "--schema-only",
        action="store_true",
        help="schema checks only: no bench run or baseline needed (toolchain-free)",
    )
    args = ap.parse_args()

    self_test()

    baseline_dir = pathlib.Path(args.baseline_dir)
    files = [pathlib.Path(f) for f in args.files]
    if args.schema_only and not files and baseline_dir.is_dir():
        files = sorted(baseline_dir.glob("BENCH_*.json"))
        if files:
            print(f"schema-only: validating committed baselines in {baseline_dir}")

    for path in files:
        if not path.exists():
            fail(f"{path}: missing")
            continue
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            fail(f"{path}: not valid JSON ({e})")
            continue
        kind = doc.get("bench")
        validator = VALIDATORS.get(kind)
        if validator is None:
            fail(f"{path}: unknown bench tag {kind!r}")
            continue
        before = len(FAILURES)
        validator(doc, str(path))
        if len(FAILURES) == before:
            print(f"{path}: schema OK ({kind})")
        if args.schema_only:
            continue
        baseline = baseline_dir / path.name
        if args.bless:
            baseline_dir.mkdir(parents=True, exist_ok=True)
            shutil.copyfile(path, baseline)
            print(f"{path}: blessed -> {baseline}")
        elif baseline.exists():
            compare_to_baseline(doc, json.loads(baseline.read_text()), kind, str(path))
        else:
            print(
                f"note: {path}: no baseline at {baseline} — run with --bless on a "
                f"trusted machine to record one"
            )

    if FAILURES:
        print(f"\n{len(FAILURES)} bench-gate failure(s)")
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
