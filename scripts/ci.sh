#!/usr/bin/env bash
# CI pipeline: format, lint, build, test, and record the perf
# trajectories (BENCH_scheduling.json latency, BENCH_throughput.json
# saturation curves).
#
# Usage: ./scripts/ci.sh [--quick]
#   --quick   lower bench instance counts (CI smoke; default 50/8)
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
  echo "error: cargo not found in PATH — this pipeline needs a Rust toolchain." >&2
  echo "       Install one via https://rustup.rs or run inside the CI image." >&2
  exit 1
fi

cd "$(dirname "$0")/../rust"

instances=200
tp_instances=50
if [[ "${1:-}" == "--quick" ]]; then
  instances=50
  tp_instances=8
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo bench --bench scheduling (instances/app=${instances})"
KERNELET_INSTANCES="${instances}" \
KERNELET_BENCH_OUT="BENCH_scheduling.json" \
  cargo bench --bench scheduling

echo "==> cargo bench --bench throughput (instances/app=${tp_instances})"
KERNELET_INSTANCES="${tp_instances}" \
KERNELET_THROUGHPUT_OUT="BENCH_throughput.json" \
  cargo bench --bench throughput

echo "==> checking BENCH_throughput.json"
if command -v python3 >/dev/null 2>&1; then
  python3 - <<'EOF'
import json

with open("BENCH_throughput.json") as fh:
    d = json.load(fh)
assert d["bench"] == "throughput", "wrong bench tag"
curves = d["curves"]
assert curves, "no curves recorded"
scenarios = {c["scenario"] for c in curves}
policies = {c["policy"] for c in curves}
assert len(scenarios) >= 3, f"need >=3 scenarios, got {sorted(scenarios)}"
assert len(policies) >= 2, f"need >=2 policies, got {sorted(policies)}"
for c in curves:
    assert c["points"], f"empty curve {c['scenario']}/{c['policy']}"
    for p in c["points"]:
        assert p["throughput_kps"] > 0, f"dead point in {c['scenario']}/{c['policy']}"
print(f"BENCH_throughput.json OK: {len(curves)} curves "
      f"({len(scenarios)} scenarios x {len(policies)} policies)")
EOF
else
  echo "warning: python3 unavailable — skipping BENCH_throughput.json schema check"
  grep -q '"bench":"throughput"' BENCH_throughput.json
fi

echo "==> perf record:"
cat BENCH_scheduling.json
echo "CI OK"
