#!/usr/bin/env bash
# CI pipeline: format, lint, docs, build, test, and record + gate the
# perf trajectories (BENCH_model.json cold-path solves + slicer search +
# prewarm counters, BENCH_scheduling.json latency + engine
# events-per-second, BENCH_throughput.json saturation + fleet curves,
# BENCH_qos.json per-class tail latency, BENCH_admission.json
# goodput/shedding under overload, BENCH_routing.json fleet deadline
# routing, BENCH_tenancy.json per-tenant fair-share isolation,
# BENCH_resilience.json availability under fault drills). Schema
# and baseline gating lives in scripts/check_bench.py.
#
# Usage: ./scripts/ci.sh [--quick]
#   --quick   lower bench instance counts (CI smoke; default 50/8/10)
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"

# Toolchain-free static lint first: it needs only python3, so it runs
# (and can fail the pipeline) even where cargo is absent.
if command -v python3 >/dev/null 2>&1; then
  echo "==> lint.py (self-test + rust tree)"
  python3 "$SCRIPT_DIR/lint.py" --self-test
  python3 "$SCRIPT_DIR/lint.py"
else
  echo "warning: python3 unavailable — skipping static lint" >&2
fi

if ! command -v cargo >/dev/null 2>&1; then
  echo "error: cargo not found in PATH — this pipeline needs a Rust toolchain." >&2
  echo "       Install one via https://rustup.rs or run inside the CI image." >&2
  echo "       (Toolchain-free containers can still validate the BENCH JSON" >&2
  echo "       shapes: python3 scripts/check_bench.py --schema-only)" >&2
  exit 1
fi

cd "$SCRIPT_DIR/../rust"

instances=200
tp_instances=50
qos_instances=40
adm_instances=40
routing_instances=25
tenancy_instances=40
resilience_instances=25
if [[ "${1:-}" == "--quick" ]]; then
  instances=50
  tp_instances=8
  qos_instances=10
  adm_instances=10
  routing_instances=8
  tenancy_instances=10
  resilience_instances=8
fi

# Known-failing tier-1 tests, one fully-qualified test name per line —
# an EXPLICIT allowlist, never a silent skip. Keep this empty unless a
# failure is understood and tracked in ROADMAP.md; with entries present
# the test run still executes everything and fails on any test NOT
# listed here.
ALLOWED_TEST_FAILURES=()

run_tests() {
  if [[ ${#ALLOWED_TEST_FAILURES[@]} -eq 0 ]]; then
    cargo test -q
    return
  fi
  echo "NOTE: running with ${#ALLOWED_TEST_FAILURES[@]} allowlisted failure(s):"
  printf '  - %s\n' "${ALLOWED_TEST_FAILURES[@]}"
  local out status=0
  out=$(cargo test 2>&1) || status=$?
  echo "$out"
  if [[ $status -eq 0 ]]; then
    echo "NOTE: allowlisted tests passed — prune ALLOWED_TEST_FAILURES in scripts/ci.sh"
    return
  fi
  local failed
  failed=$(echo "$out" | sed -n 's/^test \(.*\) \.\.\. FAILED$/\1/p' | sort -u)
  if [[ -z "$failed" ]]; then
    # Non-zero exit but no parseable test failures: a test target
    # failed to compile or a binary crashed — never allowlistable.
    echo "cargo test failed without reporting test failures (compile error or crash)"
    exit 1
  fi
  local unexpected=()
  while IFS= read -r t; do
    [[ -z "$t" ]] && continue
    local ok=0
    for a in "${ALLOWED_TEST_FAILURES[@]}"; do
      [[ "$t" == "$a" ]] && ok=1
    done
    [[ $ok -eq 0 ]] && unexpected+=("$t")
  done <<< "$failed"
  if [[ ${#unexpected[@]} -gt 0 ]]; then
    echo "unexpected test failures (not in the ci.sh allowlist):"
    printf '  - %s\n' "${unexpected[@]}"
    exit 1
  fi
  echo "all failures are allowlisted — continuing"
}

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> cargo build --release"
cargo build --release

echo "==> kernelet analyze --samples (slice-safety smoke)"
analyze_out=$(./target/release/kernelet analyze --samples)
echo "$analyze_out"
echo "$analyze_out" | grep -Eq 'histogram +UNSLICEABLE\(global-atomic\)' \
  || { echo "analyze smoke: histogram not flagged UNSLICEABLE(global-atomic)"; exit 1; }
echo "$analyze_out" | grep -Eq 'matrix_add +sliceable-with-rectify' \
  || { echo "analyze smoke: matrix_add not sliceable-with-rectify"; exit 1; }

echo "==> cargo test -q"
run_tests

echo "==> cargo bench --bench hotpaths (smoke: microbenches + ablations)"
cargo bench --bench hotpaths

echo "==> cargo bench --bench model (cold path: solves, slicer search, prewarm)"
KERNELET_MODEL_OUT="BENCH_model.json" \
  cargo bench --bench model

echo "==> cargo bench --bench scheduling (instances/app=${instances})"
KERNELET_INSTANCES="${instances}" \
KERNELET_BENCH_OUT="BENCH_scheduling.json" \
  cargo bench --bench scheduling

echo "==> cargo bench --bench throughput (instances/app=${tp_instances})"
KERNELET_INSTANCES="${tp_instances}" \
KERNELET_THROUGHPUT_OUT="BENCH_throughput.json" \
  cargo bench --bench throughput

echo "==> cargo bench --bench qos (instances/app=${qos_instances})"
KERNELET_INSTANCES="${qos_instances}" \
KERNELET_QOS_OUT="BENCH_qos.json" \
  cargo bench --bench qos

echo "==> cargo bench --bench admission (instances/app=${adm_instances})"
KERNELET_INSTANCES="${adm_instances}" \
KERNELET_ADMISSION_OUT="BENCH_admission.json" \
  cargo bench --bench admission

echo "==> cargo bench --bench routing (instances/app=${routing_instances})"
KERNELET_INSTANCES="${routing_instances}" \
KERNELET_ROUTING_OUT="BENCH_routing.json" \
  cargo bench --bench routing

echo "==> cargo bench --bench tenancy (instances/app=${tenancy_instances})"
KERNELET_INSTANCES="${tenancy_instances}" \
KERNELET_TENANCY_OUT="BENCH_tenancy.json" \
  cargo bench --bench tenancy

echo "==> cargo bench --bench resilience (instances/app=${resilience_instances})"
KERNELET_INSTANCES="${resilience_instances}" \
KERNELET_RESILIENCE_OUT="BENCH_resilience.json" \
  cargo bench --bench resilience

echo "==> bench gate (schemas + acceptance + baseline drift)"
if command -v python3 >/dev/null 2>&1; then
  python3 "$SCRIPT_DIR/check_bench.py" \
    --baseline-dir "$SCRIPT_DIR/baselines" \
    BENCH_model.json BENCH_scheduling.json BENCH_throughput.json BENCH_qos.json \
    BENCH_admission.json BENCH_routing.json BENCH_tenancy.json BENCH_resilience.json
else
  echo "warning: python3 unavailable — falling back to shape greps" >&2
  grep -q '"bench":"model"' BENCH_model.json
  grep -q '"solves_per_sec"' BENCH_model.json
  grep -q '"bench":"scheduling"' BENCH_scheduling.json
  grep -q '"bench":"throughput"' BENCH_throughput.json
  grep -q '"fleet_curves"' BENCH_throughput.json
  grep -q '"bench":"qos"' BENCH_qos.json
  grep -q '"bench":"admission"' BENCH_admission.json
  grep -q '"bench":"routing"' BENCH_routing.json
  grep -q '"bench":"tenancy"' BENCH_tenancy.json
  grep -q '"bench":"resilience"' BENCH_resilience.json
  grep -q '"flashcrowd"' BENCH_resilience.json
fi

echo "==> perf record:"
cat BENCH_scheduling.json
if command -v python3 >/dev/null 2>&1; then
  python3 - <<'EOF'
import json
ev = json.load(open("BENCH_scheduling.json")).get("events", {})
if ev:
    print(
        f"engine event rate: {ev['events_per_sec']:.0f} events/s on {ev['workload']} "
        f"({ev['total']} events in {ev['wall_s']:.4f}s)"
    )
EOF
fi
echo "CI OK"
