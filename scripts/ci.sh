#!/usr/bin/env bash
# CI pipeline: format, lint, build, test, and record the perf
# trajectories (BENCH_scheduling.json latency, BENCH_throughput.json
# saturation + fleet curves, BENCH_qos.json per-class tail latency).
#
# Usage: ./scripts/ci.sh [--quick]
#   --quick   lower bench instance counts (CI smoke; default 50/8)
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
  echo "error: cargo not found in PATH — this pipeline needs a Rust toolchain." >&2
  echo "       Install one via https://rustup.rs or run inside the CI image." >&2
  exit 1
fi

cd "$(dirname "$0")/../rust"

instances=200
tp_instances=50
qos_instances=40
if [[ "${1:-}" == "--quick" ]]; then
  instances=50
  tp_instances=8
  qos_instances=10
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo bench --bench scheduling (instances/app=${instances})"
KERNELET_INSTANCES="${instances}" \
KERNELET_BENCH_OUT="BENCH_scheduling.json" \
  cargo bench --bench scheduling

echo "==> cargo bench --bench throughput (instances/app=${tp_instances})"
KERNELET_INSTANCES="${tp_instances}" \
KERNELET_THROUGHPUT_OUT="BENCH_throughput.json" \
  cargo bench --bench throughput

echo "==> cargo bench --bench qos (instances/app=${qos_instances})"
KERNELET_INSTANCES="${qos_instances}" \
KERNELET_QOS_OUT="BENCH_qos.json" \
  cargo bench --bench qos

echo "==> checking BENCH_throughput.json"
if command -v python3 >/dev/null 2>&1; then
  python3 - <<'EOF'
import json

with open("BENCH_throughput.json") as fh:
    d = json.load(fh)
assert d["bench"] == "throughput", "wrong bench tag"
curves = d["curves"]
assert curves, "no curves recorded"
scenarios = {c["scenario"] for c in curves}
policies = {c["policy"] for c in curves}
assert len(scenarios) >= 3, f"need >=3 scenarios, got {sorted(scenarios)}"
assert len(policies) >= 2, f"need >=2 policies, got {sorted(policies)}"
for c in curves:
    assert c["points"], f"empty curve {c['scenario']}/{c['policy']}"
    for p in c["points"]:
        assert p["throughput_kps"] > 0, f"dead point in {c['scenario']}/{c['policy']}"
fleet = d["fleet_curves"]
assert fleet, "no fleet curves recorded"
routing = {c["policy"] for c in fleet}
assert routing >= {"roundrobin", "leastloaded", "sloaware"}, f"missing routing policies: {sorted(routing)}"
gpus = {c["gpus"] for c in fleet}
assert len(gpus) >= 2, f"fleet sweep must scale device counts, got {sorted(gpus)}"
for c in fleet:
    assert c["points"], f"empty fleet curve {c['scenario']}/{c['policy']}/x{c['gpus']}"
    for p in c["points"]:
        assert p["throughput_kps"] > 0, f"dead fleet point {c['scenario']}/{c['policy']}/x{c['gpus']}"
print(f"BENCH_throughput.json OK: {len(curves)} curves + {len(fleet)} fleet curves "
      f"({len(scenarios)} scenarios x {len(policies)} policies; fleets {sorted(gpus)})")
EOF
else
  echo "warning: python3 unavailable — skipping BENCH_throughput.json schema check"
  grep -q '"bench":"throughput"' BENCH_throughput.json
  grep -q '"fleet_curves"' BENCH_throughput.json
fi

echo "==> checking BENCH_qos.json"
if command -v python3 >/dev/null 2>&1; then
  python3 - <<'EOF'
import json

with open("BENCH_qos.json") as fh:
    d = json.load(fh)
assert d["bench"] == "qos", "wrong bench tag"
assert 0.0 < d["latency_fraction"] <= 1.0
assert d["deadline_scale"] > 0.0
curves = d["curves"]
assert {c["policy"] for c in curves} >= {"kernelet", "deadline"}, "missing QoS policies"
by = {(c["scenario"], c["policy"]): c["points"] for c in curves}
for pts in by.values():
    assert pts, "empty QoS curve"
    for p in pts:
        for cls in ("latency", "batch"):
            c = p[cls]
            assert c["deadline_misses"] <= max(c["with_deadline"], 1)
            assert c["p50_s"] <= c["p99_s"] + 1e-12

# Acceptance: under bursty overload the deadline policy is never worse
# than class-blind Kernelet on the latency class, and strictly better
# whenever Kernelet actually misses deadlines (a quiet quick-mode run
# where nobody misses proves nothing either way and must not fail CI).
def at_peak(policy):
    pts = by[("bursty", policy)]
    return max(pts, key=lambda p: p["load"])["latency"]

k, dl = at_peak("kernelet"), at_peak("deadline")
assert dl["p99_s"] <= k["p99_s"], f"deadline p99 {dl['p99_s']} > kernelet {k['p99_s']}"
assert dl["deadline_misses"] <= k["deadline_misses"], \
    f"deadline misses {dl['deadline_misses']} > kernelet {k['deadline_misses']}"
if k["deadline_misses"] > 0:
    assert dl["deadline_misses"] < k["deadline_misses"] or dl["p99_s"] < k["p99_s"], \
        "EDF gating bought nothing under bursty overload"
print(f"BENCH_qos.json OK: {len(curves)} curves; bursty peak latency-class "
      f"p99 {dl['p99_s']:.5f}s vs {k['p99_s']:.5f}s, "
      f"misses {dl['deadline_misses']} vs {k['deadline_misses']}")
EOF
else
  echo "warning: python3 unavailable — skipping BENCH_qos.json schema check"
  grep -q '"bench":"qos"' BENCH_qos.json
fi

echo "==> perf record:"
cat BENCH_scheduling.json
echo "CI OK"
