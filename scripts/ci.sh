#!/usr/bin/env bash
# CI pipeline: format, lint, build, test, and record the scheduling
# perf trajectory (BENCH_scheduling.json).
#
# Usage: ./scripts/ci.sh [--quick]
#   --quick   lower bench instance count (CI smoke; default 50)
set -euo pipefail

cd "$(dirname "$0")/../rust"

instances=200
if [[ "${1:-}" == "--quick" ]]; then
  instances=50
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo bench --bench scheduling (instances/app=${instances})"
KERNELET_INSTANCES="${instances}" \
KERNELET_BENCH_OUT="BENCH_scheduling.json" \
  cargo bench --bench scheduling

echo "==> perf record:"
cat BENCH_scheduling.json
echo "CI OK"
