#!/usr/bin/env python3
"""Toolchain-free static lint for the Rust tree.

CI runs `cargo fmt/clippy/rustdoc` when a toolchain exists, but the
repo must also be checkable from containers that only have python3
(the same constraint behind `check_bench.py --schema-only`). This
script covers the subset of those gates that can be checked purely
textually, stdlib only:

1. **Rustdoc coverage** — every file starts with a `//!` module doc,
   and every `pub` item (`fn`, `struct`, `enum`, `trait`, `const`,
   `static`, `type`, `union`) is preceded by a `///` doc comment
   (attributes in between are fine). `pub use` / `pub mod` re-exports
   and `pub(crate)`/`pub(super)` items are exempt, as are items inside
   `#[cfg(test)]` modules. This mirrors the `RUSTDOCFLAGS="-D
   warnings"` + `missing_docs` bar the full pipeline enforces.
2. **Delimiter balance** — `{}`, `()`, `[]` must balance per file,
   counted on a comment/string/char-literal-stripped view of the
   source (so `"}"`, `'{'` and commented braces don't miscount). An
   imbalance is almost always a truncated or mis-merged file.
3. **Stray debug macros** — `dbg!(`, `todo!(` and `unimplemented!(`
   never belong in committed code (clippy would reject the first;
   the others are unfinished work).
4. **No-alloc markers** — a `// lint: no-alloc` comment directly above
   a `fn` promises the body performs no heap allocation on the steady
   path; the checker flags `Vec::new(`, `vec![` and `.to_vec()` inside
   the marked body (scratch-reuse hot loops like the simulator engine
   and the Markov solver carry these markers).
5. **Builder bypass** — engine configuration goes through
   `EngineBuilder`; the deprecated `Engine::with_timing` /
   `with_observer` / `with_admission` shims remain only for the pinned
   builder-vs-legacy differential. New `.with_*(` call sites outside
   `engine.rs` are flagged unless covered by an explicit
   `#[allow(deprecated)]`. The two-argument
   `MultiGpuDispatcher::with_admission(spec, shed_point)` is a
   different, current API and stays exempt.

Usage:
    lint.py [--root DIR] [--self-test]

`--self-test` runs the checkers against embedded good/bad snippets and
exits non-zero if any bad snippet passes or any good snippet fails —
the same trust-but-verify pattern as `check_bench.py`'s schema
self-test. Exit status 0 = clean, 1 = findings (or self-test failure).
"""

import argparse
import pathlib
import re
import sys

PUB_ITEM = re.compile(
    r"^\s*pub\s+(?:unsafe\s+)?(?:async\s+)?(?:extern\s+\"[^\"]*\"\s+)?"
    r"(?:fn|struct|enum|trait|const|static|type|union)\b"
)
STRAY_MACROS = ("dbg!(", "todo!(", "unimplemented!(")


def strip_code(src):
    """Return `src` with comments, strings and char literals blanked.

    Preserves line structure (newlines survive) so findings can still
    be reported by line number. Handles nested `/* */`, raw strings
    (`r"..."`, `r#"..."#`), escapes inside strings, and the ambiguity
    between char literals and lifetimes (`'a` has no closing quote).
    """
    out = []
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        two = src[i : i + 2]
        if two == "//":
            while i < n and src[i] != "\n":
                i += 1
        elif two == "/*":
            depth = 1
            i += 2
            while i < n and depth:
                if src[i : i + 2] == "/*":
                    depth += 1
                    i += 2
                elif src[i : i + 2] == "*/":
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        out.append("\n")
                    i += 1
        elif c == '"' or (c == "r" and re.match(r'r#*"', src[i:])):
            if c == "r":
                hashes = 0
                i += 1
                while src[i] == "#":
                    hashes += 1
                    i += 1
                i += 1  # opening quote
                close = '"' + "#" * hashes
                end = src.find(close, i)
                end = n if end < 0 else end + len(close)
                out.extend("\n" * src.count("\n", i, end))
                i = end
            else:
                i += 1
                while i < n and src[i] != '"':
                    if src[i] == "\n":
                        out.append("\n")
                        i += 1
                    elif src[i] == "\\":
                        # Keep the newline of a backslash line
                        # continuation: dropping it would shift every
                        # later finding's line number by one.
                        if i + 1 < n and src[i + 1] == "\n":
                            out.append("\n")
                        i += 2
                    else:
                        i += 1
                i += 1
        elif c == "'":
            # Char literal iff a closing quote follows within a short
            # window ('x', '\n', '\u{1F600}'); otherwise a lifetime.
            m = re.match(r"'(\\u\{[0-9a-fA-F]{1,6}\}|\\.|[^\\'])'", src[i:])
            i += m.end() if m else 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


def check_balance(path, code, findings):
    pairs = {"}": "{", ")": "(", "]": "["}
    stack = []
    line = 1
    for c in code:
        if c == "\n":
            line += 1
        elif c in "{([":
            stack.append((c, line))
        elif c in "})]":
            if not stack or stack[-1][0] != pairs[c]:
                findings.append(f"{path}:{line}: unbalanced '{c}'")
                return
            stack.pop()
    if stack:
        c, line = stack[-1]
        findings.append(f"{path}:{line}: unclosed '{c}'")


def check_stray_macros(path, code, findings):
    for lineno, text in enumerate(code.splitlines(), 1):
        for m in STRAY_MACROS:
            if m in text:
                findings.append(f"{path}:{lineno}: stray {m[:-1]}")


ALLOC_PATTERNS = ("Vec::new(", "vec![", ".to_vec()")
NO_ALLOC_MARKER = "// lint: no-alloc"


def check_no_alloc(path, src, code, findings):
    """Flag heap allocation inside `// lint: no-alloc` marked fns.

    The marker goes on its own line directly above the `fn` (attributes
    and further comments in between are fine). The body is located by
    brace matching on the stripped view, so braces in strings or
    comments cannot derail it.
    """
    lines = src.splitlines()
    stripped = code.splitlines()
    while len(stripped) < len(lines):
        stripped.append("")
    for idx, text in enumerate(lines):
        if text.strip() != NO_ALLOC_MARKER:
            continue
        # Find the fn the marker annotates.
        j = idx + 1
        while j < len(stripped) and not re.search(r"\bfn\s+\w+", stripped[j]):
            if stripped[j].strip() and not stripped[j].strip().startswith(("#[", "]")):
                j = len(stripped)  # hit real code that isn't a fn
                break
            j += 1
        if j >= len(stripped):
            findings.append(f"{path}:{idx + 1}: no-alloc marker with no following fn")
            continue
        # Brace-match the fn body on the stripped view.
        depth = 0
        opened = False
        k = j
        while k < len(stripped):
            for ch in stripped[k]:
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
            if opened:
                for pat in ALLOC_PATTERNS:
                    if pat in stripped[k]:
                        findings.append(
                            f"{path}:{k + 1}: allocation in `{NO_ALLOC_MARKER}` fn: {pat}"
                        )
            if opened and depth <= 0:
                break
            k += 1


BUILDER_BYPASS = re.compile(r"\.with_(timing|observer|admission)\s*\(")


def _call_has_toplevel_comma(lines, idx, pos):
    """Whether the call opening at `lines[idx][pos-1]` has a `,` at
    argument depth (i.e. takes more than one argument)."""
    depth = 1
    i, j = idx, pos
    while i < len(lines):
        text = lines[i]
        while j < len(text):
            c = text[j]
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
                if depth == 0:
                    return False
            elif c == "," and depth == 1:
                return True
            j += 1
        i += 1
        j = 0
    return False


def check_builder_bypass(path, code, findings):
    """Flag legacy `Engine::with_*` configuration call sites.

    `engine.rs` itself (shim definitions, builder internals and their
    unit tests) is exempt, as is any site under an explicit
    `#[allow(deprecated)]` within the previous three lines (the pinned
    builder-vs-legacy differential) and the two-argument fleet form
    `MultiGpuDispatcher::with_admission(spec, shed_point)`.
    """
    if path.name == "engine.rs":
        return
    stripped = code.splitlines()
    for idx, text in enumerate(stripped):
        m = BUILDER_BYPASS.search(text)
        if not m:
            continue
        if m.group(1) == "admission" and _call_has_toplevel_comma(stripped, idx, m.end()):
            continue
        context = "\n".join(stripped[max(0, idx - 3) : idx + 1])
        if "#[allow(deprecated)]" in context:
            continue
        findings.append(
            f"{path}:{idx + 1}: legacy Engine::with_{m.group(1)} call site — "
            "configure through EngineBuilder instead"
        )


def test_mod_ranges(lines):
    """Line ranges (1-based, inclusive) of `#[cfg(test)] mod` bodies."""
    ranges = []
    for idx, text in enumerate(lines):
        if text.strip() != "#[cfg(test)]":
            continue
        j = idx + 1
        while j < len(lines) and lines[j].strip().startswith("#["):
            j += 1
        if j >= len(lines) or not re.match(r"\s*(pub\s+)?mod\b", lines[j]):
            continue
        depth = 0
        for k in range(j, len(lines)):
            depth += lines[k].count("{") - lines[k].count("}")
            if depth == 0 and "{" in "".join(lines[j : k + 1]):
                ranges.append((idx + 1, k + 1))
                break
    return ranges


def check_doc_coverage(path, src, findings):
    lines = src.splitlines()
    if not lines or not lines[0].startswith("//!"):
        findings.append(f"{path}:1: missing //! module doc on line 1")
    stripped = strip_code(src).splitlines()
    # Pad: strip_code drops trailing newline-less remainders evenly.
    while len(stripped) < len(lines):
        stripped.append("")
    skip = test_mod_ranges(stripped)
    for idx, text in enumerate(stripped):
        lineno = idx + 1
        if any(lo <= lineno <= hi for lo, hi in skip):
            continue
        if not PUB_ITEM.match(text):
            continue
        # Walk back over attributes and plain `//` comments (rustdoc
        # attaches docs through both — `// lint: no-alloc` markers sit
        # between the doc and the fn); a doc comment must sit directly
        # above them (a blank line breaks the attachment, matching
        # rustdoc). Comments are blanked in `stripped`, so both the
        # comment test and the doc check read the ORIGINAL line.
        j = idx - 1
        while j >= 0 and (
            stripped[j].strip().startswith("#[")
            or stripped[j].strip() == "]"
            or (
                lines[j].lstrip().startswith("//")
                and not lines[j].lstrip().startswith(("///", "//!"))
            )
        ):
            j -= 1
        if j < 0 or not lines[j].lstrip().startswith(("///", "//!")):
            item = text.strip().split("{")[0].strip()
            findings.append(f"{path}:{lineno}: undocumented pub item: {item}")


def lint_file(path, findings):
    src = path.read_text(encoding="utf-8")
    code = strip_code(src)
    check_balance(path, code, findings)
    check_stray_macros(path, code, findings)
    check_no_alloc(path, src, code, findings)
    check_builder_bypass(path, code, findings)
    if "src" in path.parts:  # doc bar applies to the library, not tests/benches
        check_doc_coverage(path, src, findings)


def run(root):
    findings = []
    files = sorted(
        p
        for sub in ("rust/src", "rust/tests", "rust/benches")
        for p in (root / sub).rglob("*.rs")
    )
    if not files:
        findings.append(f"{root}: no .rs files found (wrong --root?)")
    for path in files:
        lint_file(path, findings)
    return findings, len(files)


# --- self-test -------------------------------------------------------------

GOOD_SNIPPET = '''//! A documented module.

/// Doc'd function with tricky tokens: "}" and '{' and // inline.
#[inline]
pub fn fine(x: u32) -> u32 {
    let _s = "a string with dbg-looking text: todo is a word";
    let _c = '}';
    x + 1 /* nested /* comment */ with brace { */
}

pub(crate) fn internal_no_doc_needed() {}

/// Marked hot fn that reuses scratch instead of allocating; the line
/// continuation in the string exercises newline accounting: "a \\
/// b".
// lint: no-alloc
pub fn hot(buf: &mut Vec<u32>) -> usize {
    let _msg = "wrapped \
                line";
    buf.clear();
    buf.extend(0..4);
    buf.len()
}

#[cfg(test)]
mod tests {
    pub fn helpers_in_tests_need_no_docs() {}
}
'''

BAD_UNDOC = """//! Module doc present.

pub fn missing_docs() {}
"""

BAD_NO_MODULE_DOC = """/// An item doc is not a module doc.
pub struct S;
"""

BAD_UNBALANCED = """//! Module doc.

/// Doc.
pub fn f() { if true { }
"""

BAD_STRAY = """//! Module doc.

/// Doc.
pub fn f() {
    dbg!(42);
    todo!()
}
"""

BAD_ALLOC = """//! Module doc.

/// Doc.
// lint: no-alloc
pub fn f() -> Vec<u32> {
    let v = Vec::new();
    v
}

/// Doc.
pub fn unmarked_may_alloc() -> Vec<u32> {
    vec![1, 2, 3]
}
"""

BAD_ORPHAN_MARKER = """//! Module doc.

// lint: no-alloc
const X: u32 = 1;
"""

GOOD_BUILDER = """//! Module doc.

/// The fleet's two-argument form and an explicitly allowed legacy
/// pin are both exempt from the builder-bypass check.
pub fn g() {
    let _d = dispatcher.with_admission(spec, ShedPoint::Router);
    #[allow(deprecated)]
    let _e = Engine::new(&coord).with_admission(spec.build());
    let _b = EngineBuilder::new(&coord).admission(spec.build()).build();
}
"""

BAD_BUILDER = """//! Module doc.

/// Doc.
pub fn f() {
    let _e = Engine::new(&coord).with_timing(&timing);
}
"""


def self_test():
    failures = []

    def lint_snippet(src, name):
        findings = []
        path = pathlib.Path(f"src/{name}.rs")  # 'src' part => doc bar applies
        code = strip_code(src)
        check_balance(path, code, findings)
        check_stray_macros(path, code, findings)
        check_no_alloc(path, src, code, findings)
        check_builder_bypass(path, code, findings)
        check_doc_coverage(path, src, findings)
        return findings

    for src, name in ((GOOD_SNIPPET, "good"), (GOOD_BUILDER, "goodbuilder")):
        good = lint_snippet(src, name)
        if good:
            failures.append(f"good snippet {name!r} flagged: {good}")
    for src, name, want in (
        (BAD_UNDOC, "undoc", "undocumented"),
        (BAD_NO_MODULE_DOC, "nomod", "module doc"),
        (BAD_UNBALANCED, "unbal", "unclosed"),
        (BAD_STRAY, "stray", "stray"),
        (BAD_ALLOC, "alloc", "allocation in"),
        (BAD_ORPHAN_MARKER, "orphan", "no following fn"),
        (BAD_BUILDER, "builder", "EngineBuilder"),
    ):
        findings = lint_snippet(src, name)
        if not any(want in f for f in findings):
            failures.append(f"bad snippet {name!r} not caught (wanted {want!r}, got {findings})")
    # The no-alloc bar must apply only to MARKED fns: BAD_ALLOC also
    # contains an unmarked `vec![` fn that must stay unflagged.
    alloc_hits = [f for f in lint_snippet(BAD_ALLOC, "alloc") if "allocation in" in f]
    if len(alloc_hits) != 1:
        failures.append(f"no-alloc checker flagged {len(alloc_hits)} sites, expected 1: {alloc_hits}")
    if failures:
        for f in failures:
            print(f"SELF-TEST FAIL: {f}")
        return 1
    print("lint.py self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=None, help="repo root (default: script's parent dir)")
    ap.add_argument("--self-test", action="store_true", help="verify the checkers themselves")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    root = pathlib.Path(args.root) if args.root else pathlib.Path(__file__).resolve().parents[1]
    findings, nfiles = run(root)
    for f in findings:
        print(f"FAIL: {f}")
    if findings:
        print(f"lint: {len(findings)} finding(s) across {nfiles} files")
        sys.exit(1)
    print(f"lint OK ({nfiles} files)")


if __name__ == "__main__":
    main()
